"""Cross-process USF: the node-level coordination layer.

The paper's headline results are *multi-process*: independent processes
(nested BLAS, multi-process LLaMA inference, MD) co-located on one node,
coordinated purely in user space. This package is that layer:

* ``NodeBroker`` (broker.py) — one per node: apportions the node's slots
  across registered processes with the same lease machinery
  (``repro.core.lease``) the in-process ``SlotArbiter`` uses for jobs;
  heartbeat-based liveness reclaims a dead worker's lease. Since PR 9
  apportionment is **demand-aware**: it runs over each worker's live,
  hysteresis-damped effective want (``DemandState`` — backlog feedback
  piggybacked on heartbeats, envelope v2) instead of the static
  registration width, and regrant pushes are deduplicated (unchanged
  grants are never re-sent; ``grants_suppressed`` counts the saves).
* ``BrokerClient`` (client.py) — one per worker process: registers a
  share, receives grants, and lands them on the runtime's elastic slot
  parking (``UsfRuntime.set_slot_target``). Each heartbeat piggybacks
  the worker's instantaneous runnable backlog — the bound runtime's
  lock-free ``runnable_backlog()`` probe by default, an arbitrary
  ``backlog_probe`` (e.g. request-queue depth) otherwise;
  ``report_backlog=False`` keeps the static v1 contract.
* ``FaultPlan`` (faults.py) — a seeded, deterministic fault injector
  wrapped around a client's protocol layer (drops, delays, truncated
  frames, duplicated/reordered grants, resets, heartbeat stalls); the
  chaos suite (tests/test_chaos.py) drives it.
* ``protocol`` — the tiny length-prefixed JSON framing over Unix sockets.

Failure/recovery semantics (coordination is an optimization, never a
liveness dependency — and the system heals, it does not merely survive):

* **Degrade immediately, heal in the background.** A lost broker (EOF,
  send failure, reset) drops the worker to free-running at full local
  width at once; a reconnect loop with exponential backoff + jitter then
  re-registers it under the same name/share/demand and resumes
  coordination. The client walks a transient
  ``COORDINATED → DEGRADED → RECONNECTING → COORDINATED`` state machine
  (``BrokerClient.state``); ``reconnect=False`` restores the terminal
  degrade.
* **Epoch fencing.** Every broker start mints an ``incarnation`` id,
  sent on the ``welcome`` handshake and carried on every grant alongside
  the monotonic grant ``epoch``. Clients drop grants from a stale
  (incarnation, epoch) pair — a grant racing a reconnect can never
  shrink a worker on a dead broker's authority. A restarted broker takes
  over the rendezvous path and rebuilds its lease table purely from
  re-registrations.
* **Typed failures, never hangs.** Lease ops (``resize``/``rescale``)
  on a lost broker raise ``BrokerLostError`` (a ``ConnectionError``);
  the share change is still recorded locally and carried by the next
  re-registration (queued-or-rejected).
* **Lost-message healing.** The current grant rides every heartbeat ack,
  so a dropped grant push heals within one heartbeat interval; a
  heartbeat from an unregistered connection (lost ``register``) drops
  the connection so the worker's reconnect loop re-registers it.
* **Demand feedback degrades gracefully.** A worker that cannot probe
  its backlog (no runtime bound, probe raising) simply beats without the
  field and is treated as static-demand (v1); a *malformed* backlog —
  garbage type, negative — is a protocol violation that costs the sender
  its connection, never the broker loop or a sibling's coordination.
  Zero is a legal demand end to end (``want=0`` registration,
  ``backlog=0`` beats): the broker may grant nothing, and the liveness
  floor is applied only where grants land (``set_slot_target`` floors at
  one slot).

See docs/IPC.md for the envelope-v2 wire format and the demand model's
knobs (hysteresis depth, EWMA weight, min-regrant interval).

Scheduling is thus three-level: NodeBroker (processes) → SlotArbiter
(jobs) → intra-job policies (tasks), every level speaking leases.
"""

from repro.ipc.broker import BrokerError, NodeBroker, ProcLease
from repro.ipc.client import BrokerClient, BrokerLostError, backoff_delays
from repro.ipc.faults import FaultPlan
from repro.ipc.protocol import default_socket_path

__all__ = [
    "NodeBroker",
    "BrokerClient",
    "BrokerError",
    "BrokerLostError",
    "FaultPlan",
    "ProcLease",
    "backoff_delays",
    "default_socket_path",
]
