"""Deterministic fault injection for the broker protocol layer.

A ``FaultPlan`` is a *seeded* source of fault decisions wrapped around a
``BrokerClient``'s protocol send/recv path (pass ``faults=plan`` to the
client). Every decision comes from one ``random.Random(seed)`` stream, so
a schedule is reproducible: the same seed injects the same faults at the
same protocol steps. The injectable fault classes are the ones the
self-healing layer must survive:

* **message drops** (send and recv side) — a lost ``register`` heals via
  the broker dropping unregistered heartbeaters; a lost ``grant`` heals
  via the grant refresh riding the next heartbeat ack;
* **delays** — slow delivery must never corrupt ordering (epoch fencing);
* **truncated frames** — a partial frame poisons the stream; the broker
  drops the sender, the client reconnects;
* **duplicated / reordered grants** — must be idempotent / fenced by the
  monotonic (incarnation, epoch) guard;
* **connection resets** — the full outage machinery: degrade to
  free-running, reconnect with backoff, re-register, re-coordinate;
* **heartbeat stalls** — a silent-but-connected worker is reaped by the
  broker's heartbeat timeout and must rejoin on its own.

``horizon`` bounds the number of injected faults (then the plan disarms
itself); ``clear()`` disarms explicitly — the chaos suite injects for a
window, clears, and asserts bounded re-convergence. ``injected`` counts
every fault by kind for assertions and MTTR attribution.
"""

from __future__ import annotations

import random
from collections import Counter
from typing import Optional

from repro.ipc.protocol import _LEN

#: send/recv actions a plan can decide (PASS is implicit)
PASS = "pass"
DROP = "drop"
TRUNCATE = "truncate"
RESET = "reset"


def truncated_frame(promised: int = 64) -> bytes:
    """A frame header promising ``promised`` body bytes followed by a
    deliberately short body — the receiver blocks mid-frame until the
    sender closes, then sees EOF-mid-frame (``ProtocolError``)."""
    return _LEN.pack(promised) + b'{"op":"truncated"'


class FaultPlan:
    """Seeded fault schedule for one client's protocol layer.

    Parameters are per-event probabilities in ``[0, 1]``; all decisions
    draw from one seeded RNG stream. ``delay_range``/``stall_beats`` are
    inclusive ranges for injected delay seconds / swallowed heartbeats.
    ``horizon`` caps the total number of injected faults (None: no cap).
    """

    def __init__(self, seed: int = 0, *,
                 drop_send: float = 0.0, truncate_send: float = 0.0,
                 reset_send: float = 0.0, delay_send: float = 0.0,
                 drop_recv: float = 0.0, dup_recv: float = 0.0,
                 reorder_recv: float = 0.0, reset_recv: float = 0.0,
                 delay_recv: float = 0.0,
                 delay_range: tuple = (0.001, 0.02),
                 heartbeat_stall: float = 0.0,
                 stall_beats: tuple = (2, 6),
                 horizon: Optional[int] = None):
        self.seed = seed
        self._rng = random.Random(seed)
        self.drop_send = drop_send
        self.truncate_send = truncate_send
        self.reset_send = reset_send
        self.delay_send = delay_send
        self.drop_recv = drop_recv
        self.dup_recv = dup_recv
        self.reorder_recv = reorder_recv
        self.reset_recv = reset_recv
        self.delay_recv = delay_recv
        self.delay_range = delay_range
        self.heartbeat_stall = heartbeat_stall
        self.stall_beats = stall_beats
        self.horizon = horizon
        #: injected-fault counts by kind (chaos assertions / attribution)
        self.injected: Counter = Counter()
        self._held: Optional[dict] = None  # buffered msg (reorder in flight)
        self._stall_left = 0               # heartbeats still to swallow
        self._armed = True

    # ------------------------------------------------------------------ #
    # arming
    # ------------------------------------------------------------------ #
    @property
    def armed(self) -> bool:
        return self._armed

    def clear(self) -> None:
        """Disarm: no further faults are injected (held reorder buffers
        are released on the next recv so no message is lost forever)."""
        self._armed = False

    def total_injected(self) -> int:
        return sum(self.injected.values())

    def _fire(self, kind: str) -> bool:
        """Record one injected fault; auto-disarm past the horizon."""
        self.injected[kind] += 1
        if self.horizon is not None and self.total_injected() >= self.horizon:
            self._armed = False
        return True

    def _roll(self, p: float) -> bool:
        return p > 0.0 and self._rng.random() < p

    def _delay(self) -> float:
        lo, hi = self.delay_range
        return lo + (hi - lo) * self._rng.random()

    # ------------------------------------------------------------------ #
    # client send path
    # ------------------------------------------------------------------ #
    def send_action(self, msg: dict) -> tuple:
        """Decide the fate of one outgoing message: ``(action, delay_s)``.
        ``DROP`` swallows it, ``TRUNCATE`` replaces it with a poisoned
        partial frame, ``RESET`` severs the connection instead of
        sending."""
        if not self._armed:
            return PASS, 0.0
        delay = 0.0
        if self._roll(self.delay_send):
            delay = self._delay()
            self._fire("delay_send")
        if self._roll(self.drop_send):
            self._fire("drop_send")
            return DROP, delay
        if self._roll(self.truncate_send):
            self._fire("truncate_send")
            return TRUNCATE, delay
        if self._roll(self.reset_send):
            self._fire("reset_send")
            return RESET, delay
        return PASS, delay

    # ------------------------------------------------------------------ #
    # client recv path
    # ------------------------------------------------------------------ #
    def recv_actions(self, msg: dict) -> tuple:
        """Decide the fate of one incoming message:
        ``(action, delay_s, msgs)`` — ``msgs`` is what to actually
        deliver (possibly empty, duplicated, or swapped with a previously
        held message: the out-of-order pair the epoch fence must drop).
        ``RESET`` severs the connection (nothing delivered)."""
        if not self._armed:
            held, self._held = self._held, None
            return PASS, 0.0, ([msg, held] if held is not None else [msg])
        delay = 0.0
        if self._roll(self.delay_recv):
            delay = self._delay()
            self._fire("delay_recv")
        if self._roll(self.reset_recv):
            self._fire("reset_recv")
            return RESET, delay, []
        if self._roll(self.drop_recv):
            self._fire("drop_recv")
            return PASS, delay, []
        if self._roll(self.reorder_recv):
            if self._held is None:
                # hold this message; it is delivered AFTER its successor
                self._held = msg
                self._fire("reorder_recv")
                return PASS, delay, []
        out = [msg]
        if self._held is not None:
            out.append(self._held)  # released out of order, by design
            self._held = None
        if self._roll(self.dup_recv):
            self._fire("dup_recv")
            out = out + [dict(msg)]
        return PASS, delay, out

    # ------------------------------------------------------------------ #
    # heartbeat path
    # ------------------------------------------------------------------ #
    def stall_heartbeat(self) -> bool:
        """True if the current heartbeat should be swallowed (a stall run
        covers ``stall_beats`` consecutive beats — long enough runs trip
        the broker's heartbeat timeout and force a full rejoin)."""
        if self._stall_left > 0:
            self._stall_left -= 1
            return True
        if self._armed and self._roll(self.heartbeat_stall):
            lo, hi = self.stall_beats
            self._stall_left = self._rng.randint(lo, hi) - 1
            self._fire("heartbeat_stall")
            return True
        return False

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"FaultPlan(seed={self.seed}, armed={self._armed}, "
                f"injected={dict(self.injected)})")
