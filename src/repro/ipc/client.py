"""BrokerClient — a worker process's side of the node-level lease broker.

Connects a process (typically one ``UsfRuntime``) to the ``NodeBroker``:
registers a share-weighted node lease, heartbeats for liveness, and
applies pushed grants. ``bind(runtime)`` wires grants straight into
elastic slot parking — a broker *revoke* shrinks the runtime's effective
width at its tasks' next scheduling points (within one tick period for
preemptive policies), a *grant* unparks and refills immediately.

Heartbeats are also the **demand channel** (envelope v2): every beat
piggybacks the worker's instantaneous runnable backlog — by default the
bound runtime's lock-free ``runnable_backlog()`` probe (READY + RUNNING
tasks), overridable with ``backlog_probe`` for workers whose demand
lives elsewhere (e.g. a server process folding in its request-queue
depth). The broker apportions over this live, hysteresis-damped demand
instead of the static registration width, so an idle process's slots
flow to a saturated sibling while the idle process stays alive and
registered. ``report_backlog=False`` restores the static (v1) contract.

Failure semantics (the paper's pure-user-space stance: coordination is an
optimization, never a liveness dependency — and since PR 6, the system
*heals*, it does not merely survive):

* losing the broker (EOF, send failure, reset) **degrades the worker to
  free-running immediately** — full local width, never a hang — and then
  runs a reconnect loop with exponential backoff + jitter. On reconnect
  the client re-registers under the same name/share/demand and resumes
  coordination: the failure is a transient
  ``COORDINATED → DEGRADED → RECONNECTING → COORDINATED`` state machine,
  not a terminal flag (``reconnect=False`` restores the PR 5 terminal
  degrade);
* lease ops on a lost broker raise a typed ``BrokerLostError`` — never a
  hang. The share change is still recorded locally and carried by the
  next re-registration (queued-or-rejected, at the caller's option);
* grants are **epoch-fenced**: every grant carries the broker's
  per-start ``incarnation`` and a monotonic ``epoch``; grants from a
  stale incarnation, or out-of-order within one, are dropped
  (``stale_grants_dropped``) — a grant racing a reconnect can never
  shrink this worker on a dead broker's authority;
* grants are floored at one slot when applied to a runtime, so a miserly
  apportionment can throttle a process but never starve it.
"""

from __future__ import annotations

import os
import random
import socket
import threading
import time
from typing import Callable, Iterator, Optional

from repro.ipc import faults as _faults
from repro.ipc.protocol import ProtocolError, recv_msg, send_msg


class BrokerLostError(ConnectionError):
    """A lease op reached a lost broker. Subclasses ``ConnectionError``
    (hence ``OSError``) so pre-typed callers keep working. Carries the
    client's failure-machine state at raise time."""

    def __init__(self, message: str, *, client: "BrokerClient" = None):
        super().__init__(message)
        self.client_name = None if client is None else client.name
        self.client_state = None if client is None else client.state
        self.degraded = False if client is None else client.degraded
        self.last_grant = None if client is None else client.granted


def backoff_delays(base: float = 0.05, cap: float = 2.0, *,
                   factor: float = 2.0, jitter: float = 0.5,
                   rng: Optional[random.Random] = None) -> Iterator[float]:
    """Exponential backoff with jitter: yields ``0`` first (immediate
    first attempt), then ``base``, ``base*factor``, … capped at ``cap``,
    each inflated by up to ``jitter`` uniformly — co-located workers
    reconnecting to a restarted broker must not stampede in lockstep."""
    rng = rng or random.Random()
    yield 0.0
    delay = base
    while True:
        yield delay * (1.0 + jitter * rng.random())
        delay = min(cap, delay * factor)


class BrokerClient:
    """One process's node-lease handle.

    Parameters
    ----------
    path:                the broker's Unix socket path.
    name:                worker name (diagnostics; broker snapshots).
    share:               node-lease share weight (default 1.0).
    slots:               demand — how many node slots this process can use
                         (default: the bound runtime's topology width, or 1).
    heartbeat_interval:  seconds between heartbeats (keep well under the
                         broker's ``heartbeat_timeout``).
    backlog_probe:       zero-arg callable returning this worker's current
                         runnable backlog (non-negative int), sampled at
                         every heartbeat. Default: the bound runtime's
                         ``runnable_backlog`` (set by ``bind``); without a
                         runtime or probe, beats carry no backlog and the
                         broker applies static (v1) demand.
    report_backlog:      ``False`` omits the backlog field even when a
                         probe is available — the static-demand contract.
    reconnect:           heal after a broker loss (default). ``False`` is
                         the legacy terminal degrade: free-running forever.
    reconnect_backoff:   ``(base, cap)`` seconds for the backoff helper.
    reconnect_timeout:   give up reconnecting after this many seconds of
                         one continuous outage (None: keep trying forever).
    on_grant:            callback ``(slots:int) -> None`` for pushed grants.
    on_disconnect:       callback ``() -> None`` when the broker is lost.
    on_reconnect:        callback ``() -> None`` after a successful rejoin.
    faults:              optional ``repro.ipc.faults.FaultPlan`` wrapped
                         around this client's protocol send/recv layer.
    """

    #: failure-machine states
    CONNECTING = "connecting"
    COORDINATED = "coordinated"
    DEGRADED = "degraded"
    RECONNECTING = "reconnecting"
    STOPPED = "stopped"

    def __init__(self, path: str, *, name: str = "worker",
                 share: float = 1.0, slots: Optional[int] = None,
                 heartbeat_interval: float = 0.2,
                 backlog_probe: Optional[Callable[[], int]] = None,
                 report_backlog: bool = True,
                 reconnect: bool = True,
                 reconnect_backoff: tuple = (0.05, 2.0),
                 reconnect_timeout: Optional[float] = None,
                 on_grant: Optional[Callable[[int], None]] = None,
                 on_disconnect: Optional[Callable[[], None]] = None,
                 on_reconnect: Optional[Callable[[], None]] = None,
                 faults=None):
        self.path = path
        self.name = name
        self.share = float(share)
        self.slots = slots
        self.heartbeat_interval = float(heartbeat_interval)
        self.backlog_probe = backlog_probe
        self.report_backlog = bool(report_backlog)
        #: last backlog value a heartbeat actually carried (None before
        #: the first reporting beat, or when reporting is off)
        self.last_backlog: Optional[int] = None
        self.reconnect = bool(reconnect)
        self.reconnect_backoff = reconnect_backoff
        self.reconnect_timeout = reconnect_timeout
        self.on_grant = on_grant
        self.on_disconnect = on_disconnect
        self.on_reconnect = on_reconnect
        self._faults = faults
        self._rng = random.Random()
        self._runtime = None
        self._sock: Optional[socket.socket] = None
        self._send_lock = threading.Lock()
        self._io_thread: Optional[threading.Thread] = None
        self._beat_thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self._first_grant = threading.Event()
        self.state = self.CONNECTING
        #: the last applied grant (node slots), None before the first one
        self.granted: Optional[int] = None
        #: monotonic fence within the adopted incarnation
        self.grant_epoch = 0
        #: the broker incarnation this client last coordinated under
        self.incarnation: Optional[str] = None
        self._conn_incarnation: Optional[str] = None
        #: True while the broker is lost (cleared by a successful rejoin)
        self.degraded = False
        self.connected = False
        #: lifetime counters (introspection / chaos assertions)
        self.outages = 0
        self.reconnects = 0
        self.stale_grants_dropped = 0

    # ------------------------------------------------------------------ #
    # wiring
    # ------------------------------------------------------------------ #
    def bind(self, runtime) -> "BrokerClient":
        """Wire grants into ``runtime`` (``UsfRuntime`` or ``SimExecutor`` —
        anything with ``set_slot_target``/``topology``): a pushed grant of
        ``n`` caps the runtime at ``max(1, n)`` slots; losing the broker
        restores the full topology (free-running degrade). Unless an
        explicit ``backlog_probe`` was given, heartbeats sample the
        runtime's lock-free ``runnable_backlog()`` as the live demand
        signal. Call before ``start()``."""
        self._runtime = runtime
        if self.slots is None:
            self.slots = runtime.topology.n_slots
        if self.backlog_probe is None:
            self.backlog_probe = getattr(runtime, "runnable_backlog", None)
        return self

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def start(self, *, connect_timeout: float = 5.0) -> "BrokerClient":
        """Connect, register, and start the receiver/heartbeat threads.

        The initial connect retries with the same backoff helper the
        reconnect loop uses (a client racing broker startup — e.g. a
        gateway's server processes — settles instead of raising), bounded
        by the ``connect_timeout`` deadline; the last ``OSError`` is
        re-raised when the deadline passes."""
        if self._io_thread is not None:
            raise RuntimeError("client already started")
        deadline = time.monotonic() + float(connect_timeout)
        base, cap = self.reconnect_backoff
        last: Optional[OSError] = None
        for delay in backoff_delays(base, cap, rng=self._rng):
            if last is not None and time.monotonic() + delay >= deadline:
                raise last
            if self._stop_evt.wait(delay):
                raise BrokerLostError("client stopped during connect",
                                      client=self)
            try:
                self._connect_and_register(
                    attempt_timeout=max(0.1, deadline - time.monotonic()))
                break
            except OSError as e:
                last = e
        self.state = self.COORDINATED
        self._io_thread = threading.Thread(
            target=self._session_main, name=f"usf-broker-io-{self.name}",
            daemon=True)
        self._io_thread.start()
        self._beat_thread = threading.Thread(
            target=self._beat_main, name=f"usf-broker-beat-{self.name}",
            daemon=True)
        self._beat_thread.start()
        return self

    def stop(self, *, deregister: bool = True, timeout: float = 5.0) -> None:
        """Leave the broker cleanly (its lease is reclaimed for siblings)."""
        self._stop_evt.set()
        if deregister and self.connected:
            try:
                self._send({"op": "deregister"})
            except OSError:
                pass
        self._sever(self._sock)
        for t in (self._io_thread, self._beat_thread):
            if t is not None and t is not threading.current_thread():
                t.join(timeout)
        self.connected = False
        self.state = self.STOPPED

    # ------------------------------------------------------------------ #
    # lease ops (cross-process twins of SlotLease.resize / apply_rescale)
    # ------------------------------------------------------------------ #
    def resize(self, share: float) -> None:
        """Set this process's node share (elastic cross-process lease).

        On a lost broker this raises ``BrokerLostError`` — but the new
        share is already recorded locally, so the next re-registration
        carries it (queued-or-rejected, never a hang)."""
        self.share = float(share)
        self._send({"op": "resize", "share": self.share})

    def rescale(self, scale: float) -> None:
        """Multiply this process's node share by ``scale`` — the
        ``MeshRescaleEvent`` routing: a process that lost half its devices
        surrenders half its node-slot share to co-located processes. Same
        queued-or-rejected semantics as ``resize`` on a lost broker."""
        self.share *= float(scale)
        self._send({"op": "rescale", "scale": float(scale)})

    def wait_grant(self, timeout: Optional[float] = None) -> Optional[int]:
        """Block until the first grant is pushed; returns it (or None on
        timeout / after a degrade)."""
        self._first_grant.wait(timeout)
        return self.granted

    # ------------------------------------------------------------------ #
    # connection internals
    # ------------------------------------------------------------------ #
    def _connect_and_register(self, *, attempt_timeout: float = 1.0) -> None:
        """One connect + register attempt (start() and the reconnect loop
        both come through here). Raises ``OSError`` on failure."""
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.settimeout(attempt_timeout)
        try:
            sock.connect(self.path)
        except OSError:
            sock.close()
            raise
        sock.settimeout(None)
        with self._send_lock:
            self._sock = sock
            self._conn_incarnation = None  # adopt the peer's on welcome
            try:
                self._raw_send(sock, {
                    "op": "register",
                    "name": self.name,
                    "share": self.share,
                    # explicit 0 is legal demand (the idle-worker fix); only an
                    # unset width defaults to 1
                    "slots": 1 if self.slots is None
                    else max(0, int(self.slots)),
                    "pid": os.getpid(),
                })
            except OSError:
                self._sock = None
                try:
                    sock.close()
                except OSError:
                    pass
                raise
        self.connected = True

    def _raw_send(self, sock: socket.socket, msg: dict) -> None:
        """Frame and send one message through the fault layer (caller
        holds ``_send_lock``)."""
        if self._faults is not None:
            act, delay = self._faults.send_action(msg)
            if delay > 0.0:
                time.sleep(delay)
            if act == _faults.DROP:
                return
            if act == _faults.TRUNCATE:
                try:
                    sock.sendall(_faults.truncated_frame())
                except OSError:
                    pass
                raise OSError("injected fault: truncated frame")
            if act == _faults.RESET:
                raise OSError("injected fault: connection reset")
        send_msg(sock, msg)

    def _send(self, msg: dict) -> None:
        sock = self._sock
        if sock is None or not self.connected:
            raise BrokerLostError(
                f"broker lost ({self.state}): {msg.get('op')} not delivered"
                " — lease state is queued for the next re-registration",
                client=self)
        try:
            with self._send_lock:
                self._raw_send(sock, msg)
        except OSError as e:
            # an intentional stop() must not masquerade as a broker loss:
            # no degrade, no reconnect, no width restore on a runtime that
            # is being torn down anyway
            if not self._stop_evt.is_set():
                self._sever(sock)  # the session thread runs the outage
            if isinstance(e, BrokerLostError):
                raise
            raise BrokerLostError(
                f"broker lost mid-send: {e}", client=self) from e

    def _sever(self, sock: Optional[socket.socket]) -> None:
        """Kill the current connection; the session thread's recv wakes
        with an error and drives the degrade/reconnect machinery."""
        if sock is None:
            return
        try:
            sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            sock.close()
        except OSError:
            pass

    # ------------------------------------------------------------------ #
    # the failure state machine (session thread)
    # ------------------------------------------------------------------ #
    def _session_main(self) -> None:
        while not self._stop_evt.is_set():
            self._recv_loop()
            if self._stop_evt.is_set():
                break
            self._on_outage()
            if not self.reconnect:
                # legacy terminal degrade: free-running forever
                self._stop_evt.set()
                break
            if not self._reconnect_loop():
                break

    def _recv_loop(self) -> None:
        """Serve one connection until it is lost (returns on loss)."""
        sock = self._sock
        if sock is None:
            return
        while not self._stop_evt.is_set():
            try:
                msg = recv_msg(sock)
            except (OSError, ProtocolError, ValueError):
                msg = None
            if msg is None:  # broker gone (EOF) or socket/stream error
                return
            if self._faults is not None:
                act, delay, msgs = self._faults.recv_actions(msg)
                if delay > 0.0:
                    time.sleep(delay)
                if act == _faults.RESET:
                    self._sever(sock)
                    return
                for m in msgs:
                    self._dispatch(m)
            else:
                self._dispatch(msg)

    def _dispatch(self, msg: dict) -> None:
        op = msg.get("op")
        if op == "welcome":
            self._adopt(msg.get("incarnation"), int(msg.get("epoch", 0)))
        elif op == "grant":
            inc = msg.get("incarnation")
            if inc is not None:
                if self._conn_incarnation is None:
                    # no welcome seen (dropped, or a pre-fencing broker):
                    # adopt the first grant's incarnation
                    self._adopt(inc, int(msg.get("epoch", 1)) - 1)
                elif inc != self._conn_incarnation:
                    # a dead broker's authority can never shrink us
                    self.stale_grants_dropped += 1
                    return
            epoch = int(msg.get("epoch", self.grant_epoch + 1))
            if epoch < self.grant_epoch:
                self.stale_grants_dropped += 1  # reordered: fence it
                return
            self.grant_epoch = epoch  # == is an idempotent refresh
            self.granted = int(msg["slots"])
            self._apply_grant(self.granted)
            self._first_grant.set()
        # snapshot replies and unknown ops are ignored (forward compat)

    def _adopt(self, incarnation: Optional[str], epoch: int) -> None:
        self._conn_incarnation = incarnation
        self.incarnation = incarnation
        self.grant_epoch = epoch

    def _on_outage(self) -> None:
        """Broker lost: degrade to free-running *immediately*; healing
        (or not, with ``reconnect=False``) happens after."""
        self.outages += 1
        self.connected = False
        self.degraded = True
        self.state = self.DEGRADED
        self._first_grant.set()  # unblock wait_grant callers
        if self._runtime is not None:
            try:
                self._runtime.set_slot_target(None)  # full width again
            except Exception:  # pragma: no cover - runtime already down
                pass
        if self.on_disconnect is not None:
            self.on_disconnect()

    def _reconnect_loop(self) -> bool:
        """Retry the broker with backoff + jitter until rejoined (True),
        stopped, or the ``reconnect_timeout`` outage budget is spent."""
        self.state = self.RECONNECTING
        base, cap = self.reconnect_backoff
        deadline = (None if self.reconnect_timeout is None
                    else time.monotonic() + self.reconnect_timeout)
        for delay in backoff_delays(base, cap, rng=self._rng):
            if deadline is not None and time.monotonic() + delay > deadline:
                self.state = self.DEGRADED  # outage budget spent: stay free
                self._stop_evt.set()
                return False
            if self._stop_evt.wait(delay):
                return False
            try:
                self._connect_and_register()
            except OSError:
                continue
            self.degraded = False
            self.state = self.COORDINATED
            self.reconnects += 1
            if self.on_reconnect is not None:
                self.on_reconnect()
            return True
        return False  # pragma: no cover - backoff iterator is infinite

    # ------------------------------------------------------------------ #
    # heartbeats
    # ------------------------------------------------------------------ #
    def _beat_main(self) -> None:
        while not self._stop_evt.wait(self.heartbeat_interval):
            if not self.connected:
                continue  # outage: the session thread is reconnecting
            if self._faults is not None and self._faults.stall_heartbeat():
                continue
            try:
                self._send(self._beat_msg())
            except OSError:
                continue  # loss is handled by the session thread

    def _beat_msg(self) -> dict:
        """One heartbeat, with the live backlog piggybacked (envelope v2)
        when a probe is available. A failing probe degrades THIS beat to
        v1 (no backlog field) — demand feedback is an optimization, never
        a liveness dependency, same as coordination itself."""
        msg = {"op": "heartbeat"}
        if self.report_backlog and self.backlog_probe is not None:
            try:
                backlog = max(0, int(self.backlog_probe()))
            except Exception:
                return msg
            self.last_backlog = backlog
            msg["backlog"] = backlog
        return msg

    def _apply_grant(self, slots: int) -> None:
        if self._runtime is not None:
            # liveness floor: a zero grant throttles to one slot, never to
            # a dead stop (the runtime applies the same floor)
            self._runtime.set_slot_target(max(1, slots))
        if self.on_grant is not None:
            self.on_grant(slots)

    def __enter__(self) -> "BrokerClient":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
