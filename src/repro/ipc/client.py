"""BrokerClient — a worker process's side of the node-level lease broker.

Connects a process (typically one ``UsfRuntime``) to the ``NodeBroker``:
registers a share-weighted node lease, heartbeats for liveness, and
applies pushed grants. ``bind(runtime)`` wires grants straight into
elastic slot parking — a broker *revoke* shrinks the runtime's effective
width at its tasks' next scheduling points (within one tick period for
preemptive policies), a *grant* unparks and refills immediately.

Failure semantics (the paper's pure-user-space stance: coordination is an
optimization, never a liveness dependency):

* if the broker dies mid-run, the client detects it (EOF or send failure)
  and **degrades to free-running**: the bound runtime's width is restored
  to its full topology and the process continues uncoordinated — it never
  hangs on a dead coordinator;
* grants are floored at one slot when applied to a runtime, so a miserly
  apportionment can throttle a process but never starve it.
"""

from __future__ import annotations

import os
import socket
import threading
from typing import Callable, Optional

from repro.ipc.protocol import ProtocolError, recv_msg, send_msg


class BrokerClient:
    """One process's node-lease handle.

    Parameters
    ----------
    path:                the broker's Unix socket path.
    name:                worker name (diagnostics; broker snapshots).
    share:               node-lease share weight (default 1.0).
    slots:               demand — how many node slots this process can use
                         (default: the bound runtime's topology width, or 1).
    heartbeat_interval:  seconds between heartbeats (keep well under the
                         broker's ``heartbeat_timeout``).
    on_grant:            callback ``(slots:int) -> None`` for pushed grants.
    on_disconnect:       callback ``() -> None`` when the broker is lost.
    """

    def __init__(self, path: str, *, name: str = "worker",
                 share: float = 1.0, slots: Optional[int] = None,
                 heartbeat_interval: float = 0.2,
                 on_grant: Optional[Callable[[int], None]] = None,
                 on_disconnect: Optional[Callable[[], None]] = None):
        self.path = path
        self.name = name
        self.share = float(share)
        self.slots = slots
        self.heartbeat_interval = float(heartbeat_interval)
        self.on_grant = on_grant
        self.on_disconnect = on_disconnect
        self._runtime = None
        self._sock: Optional[socket.socket] = None
        self._send_lock = threading.Lock()
        self._recv_thread: Optional[threading.Thread] = None
        self._beat_thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self._first_grant = threading.Event()
        self._degrade_once = threading.Lock()
        #: the last pushed grant (node slots), None before the first one
        self.granted: Optional[int] = None
        self.grant_epoch = 0
        #: True once the broker was lost and this worker fell back to
        #: free-running (full local width, no coordination)
        self.degraded = False
        self.connected = False

    # ------------------------------------------------------------------ #
    # wiring
    # ------------------------------------------------------------------ #
    def bind(self, runtime) -> "BrokerClient":
        """Wire grants into ``runtime`` (``UsfRuntime`` or ``SimExecutor`` —
        anything with ``set_slot_target``/``topology``): a pushed grant of
        ``n`` caps the runtime at ``max(1, n)`` slots; losing the broker
        restores the full topology (free-running degrade). Call before
        ``start()``."""
        self._runtime = runtime
        if self.slots is None:
            self.slots = runtime.topology.n_slots
        return self

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def start(self, *, connect_timeout: float = 5.0) -> "BrokerClient":
        """Connect, register, and start the receiver/heartbeat threads."""
        if self._sock is not None:
            raise RuntimeError("client already started")
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.settimeout(connect_timeout)
        sock.connect(self.path)
        sock.settimeout(None)
        self._sock = sock
        self.connected = True
        self._send({
            "op": "register",
            "name": self.name,
            "share": self.share,
            "slots": int(self.slots or 1),
            "pid": os.getpid(),
        })
        self._recv_thread = threading.Thread(
            target=self._recv_main, name=f"usf-broker-recv-{self.name}",
            daemon=True)
        self._recv_thread.start()
        self._beat_thread = threading.Thread(
            target=self._beat_main, name=f"usf-broker-beat-{self.name}",
            daemon=True)
        self._beat_thread.start()
        return self

    def stop(self, *, deregister: bool = True, timeout: float = 5.0) -> None:
        """Leave the broker cleanly (its lease is reclaimed for siblings)."""
        self._stop_evt.set()
        if deregister and self.connected:
            try:
                self._send({"op": "deregister"})
            except OSError:
                pass
        sock = self._sock
        if sock is not None:
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                sock.close()
            except OSError:
                pass
        for t in (self._recv_thread, self._beat_thread):
            if t is not None and t is not threading.current_thread():
                t.join(timeout)
        self.connected = False

    # ------------------------------------------------------------------ #
    # lease ops (cross-process twins of SlotLease.resize / apply_rescale)
    # ------------------------------------------------------------------ #
    def resize(self, share: float) -> None:
        """Set this process's node share (elastic cross-process lease)."""
        self.share = float(share)
        self._send({"op": "resize", "share": self.share})

    def rescale(self, scale: float) -> None:
        """Multiply this process's node share by ``scale`` — the
        ``MeshRescaleEvent`` routing: a process that lost half its devices
        surrenders half its node-slot share to co-located processes."""
        self.share *= float(scale)
        self._send({"op": "rescale", "scale": float(scale)})

    def wait_grant(self, timeout: Optional[float] = None) -> Optional[int]:
        """Block until the first grant is pushed; returns it (or None on
        timeout / after a degrade)."""
        self._first_grant.wait(timeout)
        return self.granted

    # ------------------------------------------------------------------ #
    # internals
    # ------------------------------------------------------------------ #
    def _send(self, msg: dict) -> None:
        sock = self._sock
        if sock is None:
            raise OSError("not connected")
        try:
            with self._send_lock:
                send_msg(sock, msg)
        except OSError:
            # an intentional stop() must not masquerade as a broker loss:
            # no degrade flag, no on_disconnect, no width restore on a
            # runtime that is being torn down anyway
            if not self._stop_evt.is_set():
                self._degrade()
            raise

    def _recv_main(self) -> None:
        sock = self._sock
        while not self._stop_evt.is_set():
            try:
                msg = recv_msg(sock)
            except (OSError, ProtocolError, ValueError):
                msg = None
            if msg is None:  # broker gone (EOF) or socket error
                if not self._stop_evt.is_set():
                    self._degrade()
                return
            if msg.get("op") == "grant":
                self.granted = int(msg["slots"])
                self.grant_epoch = int(msg.get("epoch", self.grant_epoch + 1))
                self._apply_grant(self.granted)
                self._first_grant.set()

    def _beat_main(self) -> None:
        while not self._stop_evt.wait(self.heartbeat_interval):
            try:
                self._send({"op": "heartbeat"})
            except OSError:
                return  # _send already degraded us

    def _apply_grant(self, slots: int) -> None:
        if self._runtime is not None:
            # liveness floor: a zero grant throttles to one slot, never to
            # a dead stop (the runtime applies the same floor)
            self._runtime.set_slot_target(max(1, slots))
        if self.on_grant is not None:
            self.on_grant(slots)

    def _degrade(self) -> None:
        """Broker lost: fall back to free-running exactly once."""
        if not self._degrade_once.acquire(blocking=False):
            return
        self.degraded = True
        self.connected = False
        self._stop_evt.set()
        self._first_grant.set()  # unblock wait_grant callers
        if self._runtime is not None:
            try:
                self._runtime.set_slot_target(None)  # full width again
            except Exception:  # pragma: no cover - runtime already down
                pass
        if self.on_disconnect is not None:
            self.on_disconnect()

    def __enter__(self) -> "BrokerClient":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
