"""NodeBroker — the node-level lease broker (cross-process USF).

One broker per node apportions the node's slots across *processes*, the
same way the in-process ``SlotArbiter`` apportions one scheduler's slots
across jobs — literally with the same machinery (``repro.core.lease``):

* every registered worker process holds a node lease: a share weight
  apportioned into an integer ``quota`` by largest remainder;
* grants are **work-conserving**: capacity a worker cannot use (its
  demand is below its quota) is redistributed to wanting workers in the
  I5 borrow order (least-over-quota first), so no node slot idles while
  a sibling process has demand;
* demand is **live** (envelope v2): each heartbeat piggybacks the
  worker's instantaneous runnable backlog, and apportionment runs over
  ``effective want = clamp(backlog, 0, registered width)`` instead of
  the static registration width — an idle worker's slots flow to a
  saturated sibling while the idle process is still alive and
  registered. Demand swings are **hysteresis-damped** per worker
  (``DemandState``: K consecutive beats on the same side of the current
  effective want, an EWMA smoother, and a minimum re-grant interval), so
  a bursty backlog cannot flap grants across the node. A zero backlog is
  a legal demand (``want=0`` likewise at registration): the broker can
  grant a worker nothing — the liveness floor lives at grant
  *application* (``BrokerClient`` floors ``set_slot_target`` at one
  slot), not in the demand model. Workers that never report backlog (v1
  clients) keep the static contract: effective want == registered width;
* leases are **elastic**: ``resize``/``rescale`` ops re-apportion at
  runtime (the cross-process twin of ``SlotLease.resize``, and the
  landing point of ``MeshRescaleEvent`` routing);
* liveness is **heartbeat-based**: a worker that dies abruptly is
  detected by socket EOF (immediate) or by missed heartbeats (wedged
  process with an open socket) and its lease is reclaimed — the
  survivors' grants grow within one reaping pass;
* grants are **fenced**: every broker start mints a fresh ``incarnation``
  id, carried on the ``welcome`` handshake and on every grant alongside
  the monotonic grant ``epoch``. Clients drop grants from a stale
  (incarnation, epoch) pair, so a grant racing a reconnect can never act
  on a dead broker's authority. A restarted broker takes over the
  rendezvous path (stale socket files are probed and reclaimed) and
  rebuilds its lease table purely from the workers' re-registrations;
* delivery is **self-healing**: the current grant also rides every
  heartbeat ack, so a lost grant push heals within one heartbeat
  interval, and a heartbeat from an unregistered connection (its
  ``register`` was lost) drops the connection — the worker's reconnect
  loop re-registers it.

The broker runs as a thread in a designated process (``NodeBroker(...).
start()``) or standalone (``python -m repro.ipc.broker``). It needs no
special permissions: rendezvous is a Unix-domain socket in a user-writable
path. Workers connect through ``repro.ipc.client.BrokerClient``, whose
grants land on ``UsfRuntime.set_slot_target`` (elastic slot parking); a
dead broker degrades every worker to free-running — coordination is an
optimization, never a liveness dependency.
"""

from __future__ import annotations

import argparse
import itertools
import os
import selectors
import socket
import threading
import time
from typing import Optional

from repro.core.lease import LeaseTable, borrow_order
from repro.ipc.protocol import (
    FrameDecoder,
    ProtocolError,
    default_socket_path,
    send_msg,
)

_WID = itertools.count()


class BrokerError(RuntimeError):
    pass


class DemandState:
    """Hysteresis-damped live-demand tracker for one worker.

    Pure and deterministic — no wall-clock reads, no randomness: the
    caller supplies ``now`` with every observation, so the same beat
    sequence always yields the same decision sequence (pinned by the
    seeded determinism tests in tests/test_chaos.py).

    ``observe(backlog, now)`` folds one heartbeat's backlog sample into
    the model and returns the new effective want when the damping admits
    a move, else ``None``. The damping has three gates, all of which must
    open:

    * **side hysteresis** — the clamped sample must land on the same side
      of the current effective want for ``beats`` consecutive
      observations (a sample *at* the effective want resets the streak:
      the grant already matches demand);
    * **EWMA smoothing** — the admitted target is the smoothed backlog
      (``alpha``-weighted), clamped into [0, width] and nudged at least
      one step in the confirmed direction so a laggy average cannot veto
      a confirmed move;
    * **min-regrant interval** — at most one move per ``min_interval``
      seconds, so even a persistent sawtooth regrants boundedly.

    ``width`` is the registered demand ceiling (the worker's topology
    width); effective want always stays in [0, width]. Zero is a legal
    resting state — the model can express "this process wants nothing".
    """

    __slots__ = ("width", "eff", "ewma", "beats", "alpha", "min_interval",
                 "_side", "_streak", "_last_change", "last_backlog")

    def __init__(self, width: int, *, beats: int = 3, alpha: float = 0.5,
                 min_interval: float = 0.25):
        self.width = max(0, int(width))
        self.eff = self.width          # static until live feedback arrives
        self.ewma = float(self.eff)
        self.beats = max(1, int(beats))
        self.alpha = float(alpha)
        self.min_interval = float(min_interval)
        self._side = 0
        self._streak = 0
        self._last_change = float("-inf")
        #: last raw (clamped) sample, for introspection/snapshots
        self.last_backlog: Optional[int] = None

    def set_width(self, width: int) -> None:
        """Re-registration / resize moved the demand ceiling. A worker
        that has never reported backlog (v1 client) keeps the static
        contract — effective want tracks the new width; one with live
        feedback is clamped into the new range."""
        self.width = max(0, int(width))
        if self.last_backlog is None:
            self.eff = self.width
            self.ewma = float(self.width)
        else:
            if self.eff > self.width:
                self.eff = self.width
            self.ewma = min(self.ewma, float(self.width))

    def observe(self, backlog: int, now: float) -> Optional[int]:
        b = min(max(0, int(backlog)), self.width)
        self.last_backlog = b
        self.ewma += self.alpha * (b - self.ewma)
        side = (b > self.eff) - (b < self.eff)
        if side == 0:
            self._side = 0
            self._streak = 0
            return None
        self._streak = self._streak + 1 if side == self._side else 1
        self._side = side
        if self._streak < self.beats:
            return None
        if now - self._last_change < self.min_interval:
            return None
        target = min(max(0, int(round(self.ewma))), self.width)
        # a confirmed move must advance at least one slot even while the
        # EWMA still straddles the old value
        target = max(target, self.eff + 1) if side > 0 \
            else min(target, self.eff - 1)
        target = min(max(0, target), self.width)
        self.eff = target
        self._last_change = now
        self._side = 0
        self._streak = 0
        return target


class ProcLease:
    """One registered worker process's claim on the node's slots.

    A ``LeaseTable`` entry (``share``/``quota``/``in_use``), plus the
    broker-side connection state. ``want`` is the worker's *registered*
    demand ceiling (its own topology width; 0 is legal — a pure
    best-effort process); ``demand`` tracks its *live* effective want
    from heartbeat backlog feedback (static ``== want`` for v1 clients
    that never report backlog). ``granted`` is the pushed allotment —
    ``in_use`` mirrors it so the shared I5 borrow order applies
    unchanged. ``last_pushed`` remembers the grant content last sent on
    this connection, so an unchanged regrant is suppressed instead of
    re-pushed.
    """

    __slots__ = ("wid", "name", "pid", "share", "quota", "in_use", "want",
                 "granted", "last_beat", "conn", "demand", "last_pushed")

    def __init__(self, wid: int, name: str, pid: int, share: float,
                 want: int, conn: socket.socket, demand: DemandState):
        self.wid = wid
        self.name = name
        self.pid = pid
        self.share = share
        self.quota = 0
        self.in_use = 0
        self.want = want
        self.granted = 0
        self.last_beat = time.monotonic()
        self.conn = conn
        self.demand = demand
        #: (granted, quota) of the last successful push on this conn
        self.last_pushed: Optional[tuple] = None

    @property
    def eff_want(self) -> int:
        """The demand the apportionment sees: hysteresis-damped live
        backlog, clamped into [0, registered width]."""
        return self.demand.eff

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"ProcLease({self.name}#{self.wid} pid={self.pid} "
                f"share={self.share:.1f} {self.granted}/{self.quota} "
                f"want={self.eff_want}/{self.want})")


class NodeBroker:
    """Node-level slot broker over a Unix-domain socket.

    Parameters
    ----------
    path:               rendezvous socket path (default: per-user tmp path).
    capacity:           node slots to apportion (default: ``os.cpu_count()``).
    heartbeat_timeout:  seconds of silence before a worker is declared dead
                        and its lease reclaimed (socket EOF reclaims
                        immediately; this catches wedged-but-open workers).
    demand_beats:       hysteresis depth K — a worker's effective want
                        moves only after K consecutive heartbeats whose
                        backlog lands on the same side of it (flap
                        damping; see ``DemandState``).
    demand_alpha:       EWMA weight for the backlog smoother (1.0 = raw
                        samples, smaller = smoother).
    min_regrant_interval: per-worker floor (seconds) between demand-driven
                        effective-want moves — even a persistent backlog
                        sawtooth regrants boundedly.
    """

    def __init__(self, path: Optional[str] = None, *,
                 capacity: Optional[int] = None,
                 heartbeat_timeout: float = 1.0,
                 demand_beats: int = 3, demand_alpha: float = 0.5,
                 min_regrant_interval: float = 0.25):
        self.path = path or default_socket_path()
        self.capacity = int(capacity if capacity is not None
                            else (os.cpu_count() or 1))
        if self.capacity <= 0:
            raise BrokerError(f"capacity must be positive, got {self.capacity}")
        self.heartbeat_timeout = float(heartbeat_timeout)
        self.demand_beats = int(demand_beats)
        self.demand_alpha = float(demand_alpha)
        self.min_regrant_interval = float(min_regrant_interval)
        #: per-start incarnation id: the fencing token carried on every
        #: grant — a restarted broker can never be mistaken for its
        #: predecessor by a reconnecting client
        self.incarnation = f"{os.getpid():x}.{os.urandom(6).hex()}"
        self._table = LeaseTable(self.capacity)
        self._lock = threading.Lock()
        self._sel: Optional[selectors.BaseSelector] = None
        self._listener: Optional[socket.socket] = None
        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        #: connections whose grant push failed mid-_regrant (wedged or
        #: gone); dropped by the serve loop OUTSIDE the table lock —
        #: _drop -> _regrant from inside _regrant would deadlock
        self._dead_conns: list[socket.socket] = []
        self._epoch = 0
        #: lifetime counters (introspection / tests)
        self.registrations = 0
        self.reclaims = 0
        #: regrant passes run (any trigger: membership, share, demand)
        self.regrants = 0
        #: regrant passes triggered by a damped demand swing specifically
        self.demand_regrants = 0
        #: grant messages actually pushed by regrant passes
        self.grants_pushed = 0
        #: per-worker sends a regrant pass skipped because the grant
        #: content was unchanged (the dedupe the flap-damping test pins)
        self.grants_suppressed = 0

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def start(self) -> str:
        """Bind the socket and serve from a daemon thread; returns the
        rendezvous path (pass it to the workers' ``BrokerClient``)."""
        self._bind()
        self._thread = threading.Thread(
            target=self._serve, name="usf-node-broker", daemon=True
        )
        self._thread.start()
        return self.path

    def serve_forever(self) -> None:
        """Blocking variant (standalone broker process)."""
        self._bind()
        self._serve()

    def _bind(self) -> None:
        if self._listener is not None:
            raise BrokerError("broker already started")
        if os.path.exists(self.path):
            # never hijack a LIVE broker on a shared rendezvous path (the
            # per-user default): probe it — only a stale socket left by a
            # dead broker may be unlinked and rebound
            probe = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            probe.settimeout(0.5)
            try:
                probe.connect(self.path)
            except OSError:
                pass  # nobody listening: stale file, safe to reclaim
            else:
                raise BrokerError(
                    f"a broker is already serving {self.path}; connect a "
                    "BrokerClient to it or pick another path")
            finally:
                probe.close()
            try:
                os.unlink(self.path)
            except FileNotFoundError:
                pass
        lst = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        lst.bind(self.path)
        lst.listen(64)
        lst.setblocking(False)
        self._listener = lst
        self._sel = selectors.DefaultSelector()
        self._sel.register(lst, selectors.EVENT_READ, None)

    def stop(self, timeout: float = 5.0) -> None:
        self._stop_evt.set()
        t = self._thread
        if t is not None:
            t.join(timeout)
        self._cleanup()

    def _cleanup(self) -> None:
        with self._lock:
            for lease in list(self._table.values()):
                try:
                    lease.conn.close()
                except OSError:
                    pass
            self._table.entries.clear()
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
            self._listener = None
        if self._sel is not None:
            self._sel.close()
            self._sel = None
        try:
            os.unlink(self.path)
        except OSError:
            pass

    # ------------------------------------------------------------------ #
    # event loop
    # ------------------------------------------------------------------ #
    def _serve(self) -> None:
        sel = self._sel
        poll = min(0.05, self.heartbeat_timeout / 4)
        try:
            while not self._stop_evt.is_set():
                for key, _ in sel.select(timeout=poll):
                    if key.data is None:
                        self._accept()
                    else:
                        self._service(key.fileobj, key.data)
                self._reap_stale()
                self._flush_dead()
        finally:
            self._cleanup()

    def _accept(self) -> None:
        try:
            conn, _ = self._listener.accept()
        except OSError:
            return
        conn.setblocking(False)
        # registered with data=[lease-or-None]: the first message on the
        # connection must be `register`, which fills the cell in
        self._sel.register(conn, selectors.EVENT_READ, [None, FrameDecoder()])

    def _service(self, conn: socket.socket, cell: list) -> None:
        lease: Optional[ProcLease] = cell[0]
        decoder: FrameDecoder = cell[1]
        try:
            data = conn.recv(65536)
        except (BlockingIOError, InterruptedError):
            return
        except OSError:
            data = b""
        if not data:
            # EOF / reset: a killed worker process lands here — reclaim
            # its lease immediately (faster than the heartbeat timeout)
            self._drop(conn, cell, reclaim=True)
            return
        try:
            msgs = decoder.feed(data)
        except (ProtocolError, ValueError):
            self._drop(conn, cell, reclaim=True)
            return
        for msg in msgs:
            try:
                self._handle(conn, cell, msg)
            except Exception:
                # one malformed message (missing/mistyped fields) costs its
                # SENDER the connection — never the broker loop, and never
                # the sibling workers' coordination
                self._drop(conn, cell, reclaim=True)
                return

    def _handle(self, conn: socket.socket, cell: list, msg: dict) -> None:
        lease: Optional[ProcLease] = cell[0]
        op = msg.get("op")
        if lease is not None:
            lease.last_beat = time.monotonic()
        if op == "register":
            # the fencing handshake: the client adopts this incarnation
            # and epoch watermark before any grant of ours is applied
            try:
                send_msg(conn, {"op": "welcome",
                                "incarnation": self.incarnation,
                                "epoch": self._epoch,
                                "capacity": self.capacity})
            except OSError:
                self._drop(conn, cell, reclaim=lease is not None)
                return
            with self._lock:
                if lease is None:
                    # want=0 is legal (a pure best-effort registration):
                    # the liveness floor belongs at grant application
                    # (the client floors set_slot_target at 1), never in
                    # the demand model — flooring here would pin a node
                    # slot on every idle process forever
                    want = max(0, int(msg.get("slots", 1)))
                    lease = ProcLease(
                        next(_WID),
                        str(msg.get("name", "worker")),
                        int(msg.get("pid", 0)),
                        max(0.0, float(msg.get("share", 1.0))),
                        want,
                        conn,
                        self._make_demand(want),
                    )
                    cell[0] = lease
                    self._table.add(lease.wid, lease)
                    self.registrations += 1
                else:  # re-register: update the existing lease in place
                    lease.share = max(0.0, float(msg.get("share", lease.share)))
                    lease.want = max(0, int(msg.get("slots", lease.want)))
                    lease.demand.set_width(lease.want)
                self._regrant()
        elif op == "heartbeat":
            if lease is None:
                # register precedes heartbeats; a heartbeat from an
                # unregistered connection means the register was lost.
                # Drop the connection: the worker's reconnect loop
                # re-registers it (self-healing, never a silent limbo).
                self._drop(conn, cell, reclaim=False)
            else:
                # envelope v2: the beat may piggyback the sender's live
                # runnable backlog. Absent = a v1 client (static demand,
                # fully supported); present-but-malformed = a protocol
                # violation that costs the SENDER its connection (the
                # raise lands in _service's malformed-message handler).
                if "backlog" in msg:
                    backlog = msg["backlog"]
                    if (not isinstance(backlog, int)
                            or isinstance(backlog, bool) or backlog < 0):
                        raise ProtocolError(
                            f"malformed heartbeat backlog: {backlog!r}")
                    with self._lock:
                        moved = lease.demand.observe(
                            backlog, time.monotonic())
                        if moved is not None:
                            self.demand_regrants += 1
                            self._regrant()
                # the current grant rides the ack (idempotent refresh):
                # a lost grant push heals within one heartbeat interval
                try:
                    send_msg(conn, self._grant_msg(lease, len(self._table)))
                except OSError:
                    self._drop(conn, cell, reclaim=True)
        elif op == "resize":
            if lease is not None:
                with self._lock:
                    lease.share = max(0.0, float(msg.get("share", lease.share)))
                    if "slots" in msg:
                        lease.want = max(0, int(msg["slots"]))
                        lease.demand.set_width(lease.want)
                    self._regrant()
        elif op == "rescale":
            # the MeshRescaleEvent routing: multiply the node share by the
            # surviving-device fraction (cross-process reclaim/regrowth)
            if lease is not None:
                with self._lock:
                    lease.share = max(0.0, lease.share * float(msg["scale"]))
                    self._regrant()
        elif op == "deregister":
            self._drop(conn, cell, reclaim=True)
        elif op == "stats":
            try:
                send_msg(conn, {"op": "snapshot", **self.snapshot()})
            except OSError:
                self._drop(conn, cell, reclaim=True)
        # unknown ops are ignored (forward compatibility)

    def _drop(self, conn: socket.socket, cell: list, *, reclaim: bool) -> None:
        lease: Optional[ProcLease] = cell[0]
        try:
            self._sel.unregister(conn)
        except (KeyError, ValueError):
            pass
        try:
            conn.close()
        except OSError:
            pass
        if lease is None:
            return
        cell[0] = None
        with self._lock:
            if lease.wid in self._table:
                self._table.pop(lease.wid)
                if reclaim:
                    self.reclaims += 1
                self._regrant()

    def _flush_dead(self) -> None:
        """Drop connections whose grant push failed (deferred from
        ``_regrant``, which runs under the table lock)."""
        while self._dead_conns:
            conn = self._dead_conns.pop()
            try:
                key = self._sel.get_key(conn)
            except (KeyError, ValueError):
                continue  # already dropped (EOF raced the failed push)
            self._drop(conn, key.data, reclaim=True)

    def _reap_stale(self) -> None:
        """Heartbeat liveness: reclaim leases of silent workers (wedged
        process, or a kill the socket layer has not surfaced yet)."""
        deadline = time.monotonic() - self.heartbeat_timeout
        stale = [l for l in self._table.values() if l.last_beat < deadline]
        for lease in stale:
            key = None
            try:
                key = self._sel.get_key(lease.conn)
            except (KeyError, ValueError):
                pass
            if key is not None:
                self._drop(lease.conn, key.data, reclaim=True)
            else:  # connection already gone: just reclaim the lease
                with self._lock:
                    if lease.wid in self._table:
                        self._table.pop(lease.wid)
                        self.reclaims += 1
                        self._regrant()

    # ------------------------------------------------------------------ #
    # apportionment (the LeaseTable consumer — caller holds self._lock)
    # ------------------------------------------------------------------ #
    def _make_demand(self, want: int) -> DemandState:
        return DemandState(want, beats=self.demand_beats,
                           alpha=self.demand_alpha,
                           min_interval=self.min_regrant_interval)

    def _regrant(self) -> None:
        """Recompute every worker's grant and push the *changes*.

        Quotas come from the shared largest-remainder apportionment;
        capacity a worker cannot use (its damped **effective want** — the
        live-backlog demand model, not the static registration width — is
        below its quota) is redistributed one slot at a time in the
        shared I5 borrow order: a worker only exceeds its quota after
        every under-quota worker's demand is met, the node-level grant
        rule. Workers whose grant content is unchanged are NOT re-pushed
        (``grants_suppressed``): a steady-state recompute — a heartbeat
        or no-op resize at constant demand — costs zero sends, and the
        idempotent grant copy riding every heartbeat ack remains the
        healing path for a lost push."""
        self._table.recompute()
        entries = list(self._table.values())
        for e in entries:
            e.granted = min(e.quota, e.eff_want)
            e.in_use = e.granted
        pool = self.capacity - sum(e.granted for e in entries)
        while pool > 0:
            hungry = [e for e in entries if e.eff_want > e.granted]
            if not hungry:
                break
            e = borrow_order(hungry)[0]
            e.granted += 1
            e.in_use = e.granted
            pool -= 1
        self.regrants += 1
        dirty = [e for e in entries if (e.granted, e.quota) != e.last_pushed]
        self.grants_suppressed += len(entries) - len(dirty)
        if not dirty:
            return  # nothing moved: no epoch burn, no pushes
        self._epoch += 1
        for e in dirty:
            try:
                send_msg(e.conn, self._grant_msg(e, len(entries)))
                e.last_pushed = (e.granted, e.quota)
                self.grants_pushed += 1
            except OSError:
                # a client not draining its socket (wedged) or already
                # gone: grants are tiny, so a full buffer means hundreds
                # of unread pushes — and a partial frame has corrupted
                # the stream anyway. Schedule the drop; the serve loop
                # performs it outside this lock.
                self._dead_conns.append(e.conn)

    def _grant_msg(self, e: ProcLease, n_workers: int) -> dict:
        return {
            "op": "grant",
            "slots": e.granted,
            "quota": e.quota,
            "capacity": self.capacity,
            "workers": n_workers,
            "epoch": self._epoch,
            "incarnation": self.incarnation,
        }

    # ------------------------------------------------------------------ #
    # introspection
    # ------------------------------------------------------------------ #
    def snapshot(self) -> dict:
        with self._lock:
            return {
                "capacity": self.capacity,
                "incarnation": self.incarnation,
                "epoch": self._epoch,
                "registrations": self.registrations,
                "reclaims": self.reclaims,
                "regrants": self.regrants,
                "demand_regrants": self.demand_regrants,
                "grants_pushed": self.grants_pushed,
                "grants_suppressed": self.grants_suppressed,
                "workers": self._worker_rows(),
            }

    def _worker_rows(self) -> dict:
        """Per-worker rows keyed by name — disambiguated with the unique
        wid on collision (e.g. several clients left at the default name),
        so no lease silently vanishes from the snapshot."""
        rows: dict = {}
        for l in self._table.values():
            key = l.name if l.name not in rows else f"{l.name}#{l.wid}"
            rows[key] = {
                "wid": l.wid,
                "pid": l.pid,
                "share": l.share,
                "quota": l.quota,
                "granted": l.granted,
                "want": l.want,
                "eff_want": l.eff_want,
                "backlog": l.demand.last_backlog,
            }
        return rows


def main(argv=None) -> int:
    """Standalone node broker: ``python -m repro.ipc.broker``."""
    ap = argparse.ArgumentParser(description="USF node-level lease broker")
    ap.add_argument("--path", default=None,
                    help="Unix socket path (default: per-user tmp path)")
    ap.add_argument("--capacity", type=int, default=None,
                    help="node slots to apportion (default: cpu count)")
    ap.add_argument("--heartbeat-timeout", type=float, default=1.0)
    ap.add_argument("--demand-beats", type=int, default=3,
                    help="hysteresis depth K for backlog-driven regrants")
    ap.add_argument("--demand-alpha", type=float, default=0.5,
                    help="EWMA weight for the backlog smoother")
    ap.add_argument("--min-regrant-interval", type=float, default=0.25,
                    help="per-worker floor (s) between demand regrants")
    args = ap.parse_args(argv)
    broker = NodeBroker(args.path, capacity=args.capacity,
                        heartbeat_timeout=args.heartbeat_timeout,
                        demand_beats=args.demand_beats,
                        demand_alpha=args.demand_alpha,
                        min_regrant_interval=args.min_regrant_interval)
    print(f"usf-node-broker: serving {broker.capacity} slots on "
          f"{broker.path}", flush=True)
    try:
        broker.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        broker.stop()
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
