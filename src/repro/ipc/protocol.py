"""Wire protocol for the node-level broker (repro.ipc).

Pure user-space, no special permissions (the paper's constraint): framed
JSON over a Unix-domain stream socket. Every message is a 4-byte
big-endian length prefix followed by a UTF-8 JSON object with an ``op``
field. The framing is deliberately tiny — the broker exchanges a handful
of control messages per second, not data.

Client → broker ops (envelope v2; v1 differences noted inline)
    register    {name, share, slots, pid}      join the node lease table
                (``slots`` is the worker's registered width — the demand
                *ceiling*; 0 is legal: a pure best-effort process)
    heartbeat   {backlog?}                     liveness (and keepalive).
                v2 piggybacks ``backlog``: the sender's instantaneous
                runnable backlog (READY + RUNNING tasks), a non-negative
                int. The broker clamps it into [0, registered width] and
                feeds the demand model (hysteresis-damped effective
                want). v1 clients omit the field and keep the static
                contract: effective want == registered width. A present
                but malformed ``backlog`` (non-int, bool, or negative)
                is a protocol violation and costs the SENDER its
                connection — never the broker loop.
    resize      {share, slots?}                set this worker's share
                (and optionally its registered width)
    rescale     {scale}                        multiply share (mesh rescale)
    deregister  {}                             leave cleanly
    stats       {}                             request a table snapshot

Broker → client ops
    grant       {slots, quota, capacity, workers, epoch, incarnation}
                the worker's current node-slot grant (pushed when — and
                since envelope v2 *only* when — this worker's grant
                content changed; ``quota`` is the lease entitlement
                before work-conserving redistribution). Unchanged grants
                are not re-pushed: the idempotent copy riding every
                heartbeat ack is the refresh/healing path.
    snapshot    {...}                          reply to ``stats``

Version negotiation is deliberately absent: v2 is a pure superset (one
optional heartbeat field), so v1 clients and v2 brokers — and vice
versa — interoperate with static-demand semantics.
"""

from __future__ import annotations

import json
import os
import socket
import struct
import tempfile
from typing import Optional

_LEN = struct.Struct(">I")

#: sanity cap — control messages are tiny; anything bigger is corruption
MAX_MSG = 1 << 20


class ProtocolError(RuntimeError):
    pass


def send_msg(sock: socket.socket, msg: dict) -> None:
    """Frame and send one message (atomic wrt other senders only if the
    caller serializes — both endpoints hold a send lock)."""
    body = json.dumps(msg, separators=(",", ":")).encode("utf-8")
    sock.sendall(_LEN.pack(len(body)) + body)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """Read exactly ``n`` bytes; None on clean EOF at a frame boundary."""
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(n - got)
        if not chunk:
            if got == 0:
                return None
            raise ProtocolError(f"EOF mid-frame ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_msg(sock: socket.socket) -> Optional[dict]:
    """Receive one framed message; None on clean EOF (peer closed)."""
    head = _recv_exact(sock, _LEN.size)
    if head is None:
        return None
    (n,) = _LEN.unpack(head)
    if n > MAX_MSG:
        raise ProtocolError(f"frame of {n} bytes exceeds MAX_MSG")
    body = _recv_exact(sock, n)
    if body is None:
        raise ProtocolError("EOF between header and body")
    return json.loads(body.decode("utf-8"))


class FrameDecoder:
    """Incremental decoder for the broker's non-blocking event loop: feed
    raw bytes, pop complete messages."""

    __slots__ = ("_buf",)

    def __init__(self) -> None:
        self._buf = bytearray()

    def feed(self, data: bytes) -> list[dict]:
        self._buf.extend(data)
        out: list[dict] = []
        buf = self._buf
        while True:
            if len(buf) < _LEN.size:
                break
            (n,) = _LEN.unpack(buf[: _LEN.size])
            if n > MAX_MSG:
                raise ProtocolError(f"frame of {n} bytes exceeds MAX_MSG")
            if len(buf) < _LEN.size + n:
                break
            body = bytes(buf[_LEN.size: _LEN.size + n])
            del buf[: _LEN.size + n]
            out.append(json.loads(body.decode("utf-8")))
        return out


def default_socket_path(tag: str = "node") -> str:
    """A per-user default rendezvous path (pure user-space: no /var/run)."""
    return os.path.join(
        tempfile.gettempdir(), f"usf-broker-{tag}-{os.getuid()}.sock"
    )
