"""Mamba-2 (SSD — state-space duality) mixer block [arXiv:2405.21060].

TPU adaptation notes (DESIGN.md §2):
* The chunked SSD algorithm maps naturally to the MXU: intra-chunk terms are
  (Q x Q) matmuls, inter-chunk terms are small state GEMMs, chained by a
  ``lax.scan`` carrying the [B, H, P, N] state. The Pallas kernel
  (kernels/ssd_scan.py) implements the same chunk body with VMEM tiling.
* We convolve only the x-branch (not xBC concatenated) so the depthwise conv
  channel dim stays cleanly sharded over the model axis; B/C are small
  (n_groups=1) and stay replicated.

Recurrence (per head h, discretized):
    a_t = exp(dt_t * A)                 (A < 0)
    h_t = a_t * h_{t-1} + dt_t * B_t x_t^T
    y_t = C_t . h_t + D * x_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ParamSpec
from repro.models.layers import rmsnorm


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def mamba2_specs(cfg) -> dict:
    d = cfg.d_model
    d_inner, nh, hd, ds = _dims(cfg)
    k = cfg.ssm_conv
    return {
        "wz": ParamSpec((d, d_inner), ("embed", "mlp"), init="fan_in"),
        "wx": ParamSpec((d, d_inner), ("embed", "mlp"), init="fan_in"),
        "wB": ParamSpec((d, ds), ("embed", "state"), init="fan_in"),
        "wC": ParamSpec((d, ds), ("embed", "state"), init="fan_in"),
        "wdt": ParamSpec((d, nh), ("embed", "ssm_heads"), init="fan_in"),
        "dt_bias": ParamSpec((nh,), ("ssm_heads",), init="zeros"),
        "A_log": ParamSpec((nh,), ("ssm_heads",), init="zeros"),
        "D": ParamSpec((nh,), ("ssm_heads",), init="ones"),
        "conv_w": ParamSpec((k, d_inner), ("conv", "mlp"), init="normal",
                            scale=0.1),
        "conv_b": ParamSpec((d_inner,), ("mlp",), init="zeros"),
        "norm": ParamSpec((d_inner,), ("mlp",), init="ones"),
        "wo": ParamSpec((d_inner, d), ("mlp", "embed"), init="fan_in"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq. x [B,S,Ci], w [K,Ci]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):  # K=4: unrolled taps (elementwise FMAs)
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out + b


def ssd_chunked(x, dt, A, Bm, Cm, chunk, h0=None):
    """Chunked SSD scan.

    x [B,S,H,P]; dt [B,S,H] (>0); A [H] (<0); Bm, Cm [B,S,N] (n_groups=1).
    Returns y [B,S,H,P] and final state [B,H,P,N].
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    xc = jnp.moveaxis(x.reshape(Bsz, nc, Q, H, P), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(Bsz, nc, Q, H), 1, 0)
    Bc = jnp.moveaxis(Bm.reshape(Bsz, nc, Q, N), 1, 0)
    Cc = jnp.moveaxis(Cm.reshape(Bsz, nc, Q, N), 1, 0)

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    tri = jnp.tril(jnp.ones((Q, Q), bool))  # i >= j

    def body(h, xs):
        xq, dtq, Bq, Cq = xs  # [B,Q,H,P], [B,Q,H], [B,Q,N], [B,Q,N]
        xq32 = xq.astype(jnp.float32)
        dA = dtq.astype(jnp.float32) * A  # [B,Q,H], negative
        cum = jnp.cumsum(dA, axis=1)      # [B,Q,H]
        # intra-chunk: scores_ij = (C_i.B_j) * exp(cum_i - cum_j) * dt_j
        CB = jnp.einsum("bin,bjn->bij", Cq.astype(jnp.float32),
                        Bq.astype(jnp.float32))            # [B,Q,Q]
        decay = jnp.exp(
            jnp.clip(cum[:, :, None, :] - cum[:, None, :, :], -60.0, 0.0)
        )                                                   # [B,Q,Q,H]
        scores = CB[..., None] * decay * dtq[:, None, :, :]
        scores = jnp.where(tri[None, :, :, None], scores, 0.0)
        y_intra = jnp.einsum("bijh,bjhp->bihp", scores, xq32)
        # cross-chunk: y_i += exp(cum_i) * C_i . h_in
        Ch = jnp.einsum("bin,bhpn->bihp", Cq.astype(jnp.float32), h)
        y_cross = Ch * jnp.exp(cum)[..., None].transpose(0, 1, 2, 3)
        # state update: h' = exp(cum_Q) h + sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T
        last = cum[:, -1:, :]                               # [B,1,H]
        w = jnp.exp(jnp.clip(last - cum, -60.0, 0.0)) * dtq  # [B,Q,H]
        h_new = (
            jnp.exp(last[:, 0])[:, :, None, None] * h
            + jnp.einsum("bjh,bjn,bjhp->bhpn", w, Bq.astype(jnp.float32), xq32)
        )
        return h_new, (y_intra + y_cross).astype(x.dtype)

    h_final, yc = jax.lax.scan(body, h0, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(Bsz, S, H, P)
    return y, h_final


def mamba2_block(params: dict, cfg, sharder, x: jax.Array,
                 h0=None, conv_state=None, *, return_state: bool = False):
    """Full-sequence mixer. x [B,S,d] -> y [B,S,d] (+states if asked)."""
    dt_ = x.dtype
    d_inner, nh, hd, ds = _dims(cfg)
    B, S, _ = x.shape

    z = jnp.einsum("bsd,di->bsi", x, params["wz"].astype(dt_))
    xi = jnp.einsum("bsd,di->bsi", x, params["wx"].astype(dt_))
    xi = sharder.constrain(xi, "act_batch", None, "act_mlp")
    Bm = jnp.einsum("bsd,dn->bsn", x, params["wB"].astype(dt_))
    Cm = jnp.einsum("bsd,dn->bsn", x, params["wC"].astype(dt_))
    dtv = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, params["wdt"].astype(dt_)).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32)
    )

    if conv_state is not None:  # prefill continuation — not used in v1
        raise NotImplementedError
    xi = _causal_conv(xi, params["conv_w"].astype(dt_),
                      params["conv_b"].astype(dt_))
    xi = jax.nn.silu(xi)

    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xi.reshape(B, S, nh, hd)
    y, h_final = ssd_chunked(xh, dtv, A, Bm, Cm, cfg.ssm_chunk, h0)
    y = y + xh * params["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(B, S, d_inner)
    # gated RMSNorm (mamba2): norm(y) * silu(z)
    y = rmsnorm(y, params["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, params["wo"].astype(dt_))
    if return_state:
        return out, h_final
    return out


# --------------------------------------------------------------------------- #
# decode
# --------------------------------------------------------------------------- #
def mamba2_cache_specs(cfg, batch: int) -> dict:
    d_inner, nh, hd, ds = _dims(cfg)
    k = cfg.ssm_conv
    return {
        "h": ParamSpec((batch, nh, hd, ds), ("kv_batch", "ssm_heads", None, None),
                       init="zeros", dtype="float32"),
        "conv": ParamSpec((batch, k - 1, d_inner), ("kv_batch", None, "mlp"),
                          init="zeros", dtype=cfg.compute_dtype),
    }


def mamba2_decode(params: dict, cfg, sharder, x: jax.Array, cache: dict):
    """Single-token step. x [B,1,d] -> (y [B,1,d], new cache)."""
    dt_ = x.dtype
    d_inner, nh, hd, ds = _dims(cfg)
    B = x.shape[0]

    z = jnp.einsum("bsd,di->bsi", x, params["wz"].astype(dt_))[:, 0]
    xi = jnp.einsum("bsd,di->bsi", x, params["wx"].astype(dt_))[:, 0]
    Bm = jnp.einsum("bsd,dn->bsn", x, params["wB"].astype(dt_))[:, 0]
    Cm = jnp.einsum("bsd,dn->bsn", x, params["wC"].astype(dt_))[:, 0]
    dtv = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, params["wdt"].astype(dt_))[:, 0]
        .astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # [B,H]

    # causal conv against the rolling buffer
    conv_in = jnp.concatenate([cache["conv"], xi[:, None, :].astype(cache["conv"].dtype)], axis=1)
    w = params["conv_w"].astype(dt_)  # [K, Ci]
    conv_out = jnp.einsum("bki,ki->bi", conv_in.astype(dt_), w) + params["conv_b"].astype(dt_)
    xi = jax.nn.silu(conv_out)
    new_conv = conv_in[:, 1:, :]

    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xi.reshape(B, nh, hd).astype(jnp.float32)
    a = jnp.exp(dtv * A)  # [B,H]
    h = cache["h"] * a[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dtv, Bm.astype(jnp.float32), xh
    )
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h)
    y = y + xh * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, d_inner).astype(dt_)
    y = rmsnorm(y, params["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bi,id->bd", y, params["wo"].astype(dt_))[:, None, :]
    return out, {"h": h, "conv": new_conv}
