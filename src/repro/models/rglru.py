"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence (per channel, with head-block-diagonal gates):
    r_t = sigmoid(W_a . x_t)        recurrence gate
    i_t = sigmoid(W_x . x_t)        input gate
    a_t = exp(c * r_t * log(sigmoid(Lambda)))          (0 < a_t < 1, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Full-sequence evaluation uses ``lax.associative_scan`` over (a, b) pairs —
O(S log S) elementwise work, fully parallel, no sequential bottleneck on
the MXU-free part of the chip. The decode path is the O(1) recurrence.

The Griffin *recurrent block* wraps the RG-LRU with: linear-in, causal
depthwise conv (k=4), and a gated (GeLU) side branch, then linear-out.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ParamSpec

_C = 8.0


def _heads(cfg):
    width = cfg.lru_width or cfg.d_model
    nh = cfg.n_heads
    assert width % nh == 0
    return width, nh, width // nh


def rglru_specs(cfg) -> dict:
    d = cfg.d_model
    width, nh, hd = _heads(cfg)
    k = cfg.ssm_conv
    return {
        "w_in": ParamSpec((d, width), ("embed", "lru"), init="fan_in"),
        "w_gate_branch": ParamSpec((d, width), ("embed", "lru"), init="fan_in"),
        "conv_w": ParamSpec((k, width), ("conv", "lru"), init="normal", scale=0.1),
        "conv_b": ParamSpec((width,), ("lru",), init="zeros"),
        # block-diagonal (per-head) gate projections
        "wa": ParamSpec((nh, hd, hd), ("heads", None, None), init="fan_in"),
        "ba": ParamSpec((nh, hd), ("heads", None), init="zeros"),
        "wx": ParamSpec((nh, hd, hd), ("heads", None, None), init="fan_in"),
        "bx": ParamSpec((nh, hd), ("heads", None), init="zeros"),
        "lam": ParamSpec((width,), ("lru",), init="normal", scale=0.5),
        "w_out": ParamSpec((width, d), ("lru", "embed"), init="fan_in"),
    }


def _causal_conv(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out + b


def _gates(params, xh):
    """xh [B,S,H,hd] -> (a_t [B,S,H,hd] in (0,1), gated input)."""
    r = jax.nn.sigmoid(
        jnp.einsum("bshp,hpq->bshq", xh, params["wa"].astype(xh.dtype))
        + params["ba"].astype(xh.dtype)
    )
    i = jax.nn.sigmoid(
        jnp.einsum("bshp,hpq->bshq", xh, params["wx"].astype(xh.dtype))
        + params["bx"].astype(xh.dtype)
    )
    return r.astype(jnp.float32), i.astype(jnp.float32)


def rglru_scan(params, cfg, x, h0=None):
    """x [B,S,W] -> (y [B,S,W], h_final [B,W]). fp32 recurrence."""
    B, S, W = x.shape
    width, nh, hd = _heads(cfg)
    xh = x.reshape(B, S, nh, hd)
    r, i = _gates(params, xh)
    lam = params["lam"].astype(jnp.float32).reshape(nh, hd)
    log_a_base = jax.nn.log_sigmoid(lam)  # log(sigmoid(Lambda)) < 0
    log_a = _C * r * log_a_base           # [B,S,H,hd]
    a = jnp.exp(log_a)
    gated = i * xh.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    if h0 is not None:
        # fold h0 into the first step: h_1 = a_1 h0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0.reshape(B, nh, hd))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = h.reshape(B, S, W).astype(x.dtype)
    return y, h[:, -1].reshape(B, W)


def rglru_block(params: dict, cfg, sharder, x: jax.Array,
                h0=None, *, return_state: bool = False):
    """Griffin recurrent block. x [B,S,d] -> y [B,S,d]."""
    dt_ = x.dtype
    u = jnp.einsum("bsd,dw->bsw", x, params["w_in"].astype(dt_))
    u = sharder.constrain(u, "act_batch", None, "act_mlp")
    u = _causal_conv(u, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_))
    y, h_final = rglru_scan(params, cfg, u, h0)
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, params["w_gate_branch"].astype(dt_))
    )
    y = y * gate
    out = jnp.einsum("bsw,wd->bsd", y, params["w_out"].astype(dt_))
    if return_state:
        return out, h_final
    return out


# --------------------------------------------------------------------------- #
# decode
# --------------------------------------------------------------------------- #
def rglru_cache_specs(cfg, batch: int) -> dict:
    width, _, _ = _heads(cfg)
    k = cfg.ssm_conv
    return {
        "h": ParamSpec((batch, width), ("kv_batch", "lru"), init="zeros",
                       dtype="float32"),
        "conv": ParamSpec((batch, k - 1, width), ("kv_batch", None, "lru"),
                          init="zeros", dtype=cfg.compute_dtype),
    }


def rglru_decode(params: dict, cfg, sharder, x: jax.Array, cache: dict):
    """x [B,1,d] -> (y [B,1,d], new cache)."""
    dt_ = x.dtype
    width, nh, hd = _heads(cfg)
    B = x.shape[0]
    u = jnp.einsum("bsd,dw->bsw", x, params["w_in"].astype(dt_))[:, 0]
    conv_in = jnp.concatenate(
        [cache["conv"], u[:, None, :].astype(cache["conv"].dtype)], axis=1
    )
    w = params["conv_w"].astype(dt_)
    u = jnp.einsum("bkw,kw->bw", conv_in.astype(dt_), w) + params["conv_b"].astype(dt_)
    new_conv = conv_in[:, 1:, :]

    uh = u.reshape(B, 1, nh, hd)
    r, i = _gates(params, uh)
    lam = params["lam"].astype(jnp.float32).reshape(nh, hd)
    log_a = _C * r[:, 0] * jax.nn.log_sigmoid(lam)
    a = jnp.exp(log_a)
    gated = i[:, 0] * uh[:, 0].astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    h = a * cache["h"].reshape(B, nh, hd) + b
    y = h.reshape(B, width).astype(dt_)
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, params["w_gate_branch"].astype(dt_))[:, 0]
    )
    out = jnp.einsum("bw,wd->bd", y * gate, params["w_out"].astype(dt_))
    return out[:, None, :], {"h": h.reshape(B, width), "conv": new_conv}
