"""Module-lite parameter system: pure-JAX, no flax.

A model is (param_specs(cfg) -> spec tree, apply fns). ``ParamSpec`` holds
shape + *logical axes* + initializer; trees of specs convert to:

* real parameters (``init_tree``) for smoke tests / the 100M example,
* ShapeDtypeStructs (``abstract_tree``) for the dry-run (no allocation),
* logical-axes trees (``axes_tree``) that the Sharder resolves to
  NamedShardings for pjit in/out shardings.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"          # zeros | ones | normal | fan_in | embed
    scale: Optional[float] = None  # stddev override
    dtype: Optional[str] = None    # override the model param dtype

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"spec rank mismatch: {self.shape} vs {self.axes}")

    def stacked(self, n: int, axis_name: str = "layers") -> "ParamSpec":
        """Add a leading scan dimension (stacked per-layer params)."""
        return ParamSpec(
            (n, *self.shape), (axis_name, *self.axes), self.init, self.scale, self.dtype
        )


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def _resolve_dtype(spec: ParamSpec, default: str) -> jnp.dtype:
    return jnp.dtype(spec.dtype or default)


def _initializer(spec: ParamSpec) -> Callable[[jax.Array, tuple, Any], jax.Array]:
    kind = spec.init

    def init(key, shape, dtype):
        if kind == "zeros":
            return jnp.zeros(shape, dtype)
        if kind == "ones":
            return jnp.ones(shape, dtype)
        if kind == "const":
            return jnp.full(shape, spec.scale, dtype)
        if kind == "normal":
            std = spec.scale if spec.scale is not None else 0.02
            return (jax.random.normal(key, shape) * std).astype(dtype)
        if kind == "fan_in":
            # truncated-normal-ish scaled by 1/sqrt(fan_in); fan_in = prod of
            # all dims but the last (after any leading stack dims handled by
            # caller order: [..., in, out])
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = (spec.scale or 1.0) / math.sqrt(max(fan_in, 1))
            return (jax.random.normal(key, shape) * std).astype(dtype)
        if kind == "embed":
            std = spec.scale if spec.scale is not None else 1.0
            return (jax.random.normal(key, shape) * std).astype(dtype)
        raise ValueError(f"unknown init {kind}")

    return init


def init_tree(key: jax.Array, specs: Any, param_dtype: str = "float32") -> Any:
    """Materialize real parameters (smoke tests, the 100M example)."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, spec in zip(keys, leaves):
        dtype = _resolve_dtype(spec, param_dtype)
        out.append(_initializer(spec)(k, spec.shape, dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_tree(specs: Any, param_dtype: str = "float32") -> Any:
    """ShapeDtypeStruct stand-ins for the dry-run (no device allocation)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, _resolve_dtype(s, param_dtype)),
        specs,
        is_leaf=is_spec,
    )


def axes_tree(specs: Any) -> Any:
    """The logical-axes tree mirroring the param tree."""
    return jax.tree_util.tree_map(lambda s: s.axes, specs, is_leaf=is_spec)


def shardings_tree(specs: Any, sharder, param_dtype: str = "float32") -> Any:
    """NamedShardings mirroring the param tree (for jit in/out_shardings)."""
    return jax.tree_util.tree_map(
        lambda s: sharder.sharding(s.shape, s.axes), specs, is_leaf=is_spec
    )


def param_count(specs: Any) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return sum(math.prod(s.shape) for s in leaves)


def param_bytes(specs: Any, param_dtype: str = "float32") -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return sum(
        math.prod(s.shape) * jnp.dtype(_resolve_dtype(s, param_dtype)).itemsize
        for s in leaves
    )
