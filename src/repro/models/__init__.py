from repro.models.base import ParamSpec, init_tree, abstract_tree, axes_tree

__all__ = ["ParamSpec", "init_tree", "abstract_tree", "axes_tree"]
