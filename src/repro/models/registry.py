"""Architecture registry: config -> model instance / specs."""

from __future__ import annotations

from repro.models.transformer import LM


def build_model(cfg) -> LM:
    return LM(cfg)


def build_param_specs(cfg) -> dict:
    return LM(cfg).param_specs()
