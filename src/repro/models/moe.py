"""Mixture-of-Experts layer (deepseek-moe, grok-1).

Design (GShard-style, TPU-native):

* top-k routing with **per-row capacity** (groups = batch rows, which align
  with the data shards — so position-in-expert cumsums never cross shards).
* token dispatch via **batched scatter** into an [rows, E, C, d] buffer
  (never materializes the [T, E, C] one-hot tensor, which is astronomically
  large at pod scale); combine via batched gather.
  ``moe_impl="onehot"`` provides the classic einsum dispatch for small
  shapes / cross-checking.
* expert FFNs computed with the experts dim sharded over the model axis
  when divisible (EP: GSPMD inserts the all-to-all at the x_e constraint);
  otherwise expert weights shard over (embed->data, mlp->model) like dense
  weights (grok: 8 experts on a 16-way axis).
* optional shared experts (deepseek: 2 shared + 64 routed top-6).
* load-balancing aux loss (Switch/GShard form) + router z-loss.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.base import ParamSpec
from repro.models.layers import mlp, mlp_specs


def moe_specs(cfg) -> dict:
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    specs = {
        "router": ParamSpec((d, E), ("embed", "experts"), init="fan_in",
                            dtype="float32"),
        "wg": ParamSpec((E, d, ff), ("experts", "embed", "mlp"), init="fan_in"),
        "wu": ParamSpec((E, d, ff), ("experts", "embed", "mlp"), init="fan_in"),
        "wd": ParamSpec((E, ff, d), ("experts", "mlp", "embed"), init="fan_in"),
    }
    if cfg.n_shared_experts:
        specs["shared"] = mlp_specs(d, cfg.n_shared_experts * ff, "silu")
    return specs


def _capacity(tokens_per_row: int, n_experts: int, top_k: int, cf: float) -> int:
    c = int(tokens_per_row * top_k * cf / n_experts) + 1
    return max(4, min(c, tokens_per_row * top_k))


def moe_block(params: dict, cfg, sharder, x: jax.Array,
              *, impl: str = "scatter") -> tuple[jax.Array, dict]:
    """x: [B, S, d] -> (y [B, S, d], aux losses)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(S, E, K, cfg.capacity_factor)
    dt = x.dtype

    # ---- routing (fp32) ------------------------------------------------- #
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, K)          # [B,S,K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    # ---- aux losses ------------------------------------------------------ #
    me = probs.mean(axis=(0, 1))                        # [E] mean router prob
    ce = jnp.zeros((E,), jnp.float32)
    for j in range(K):
        ce = ce + jax.nn.one_hot(eidx[..., j], E, dtype=jnp.float32).mean((0, 1))
    ce = ce / K
    aux_loss = cfg.moe_aux_loss * E * jnp.sum(me * ce)
    z_loss = 1e-3 * jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- position-in-expert (per row: cumsums stay shard-local) ---------- #
    pos_list, keep_list = [], []
    counts = jnp.zeros((B, E), jnp.int32)
    for j in range(K):
        oh = jax.nn.one_hot(eidx[..., j], E, dtype=jnp.int32)      # [B,S,E]
        pos_full = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]
        pos = jnp.take_along_axis(
            pos_full, eidx[..., j][..., None], axis=-1
        )[..., 0]                                                   # [B,S]
        keep = pos < C
        pos_list.append(pos)
        keep_list.append(keep)
        counts = counts + oh.sum(axis=1)
    pos_k = jnp.stack(pos_list, axis=-1)    # [B,S,K]
    keep_k = jnp.stack(keep_list, axis=-1)  # [B,S,K]

    if impl == "scatter":
        # dispatch: batched scatter-add into [B, E, C, d]
        eidx_f = jnp.where(keep_k, eidx, E)         # ->dropped
        pos_f = jnp.where(keep_k, pos_k, C)

        def row_dispatch(xr, er, pr):
            # xr [S,d]; er,pr [S,K]
            buf = jnp.zeros((E, C, d), dt)
            xs = jnp.repeat(xr[:, None, :], K, axis=1).reshape(S * K, d)
            return buf.at[er.reshape(-1), pr.reshape(-1)].add(
                xs, mode="drop"
            )

        x_e = jax.vmap(row_dispatch)(x, eidx_f, pos_f)   # [B,E,C,d]
    else:  # onehot (reference; small shapes only)
        disp = jnp.zeros((B, S, E, C), jnp.float32)
        for j in range(K):
            oh_e = jax.nn.one_hot(eidx[..., j], E, dtype=jnp.float32)
            oh_c = jax.nn.one_hot(pos_k[..., j], C, dtype=jnp.float32)
            disp = disp + (
                oh_e[..., None] * oh_c[..., None, :]
                * keep_k[..., j][..., None, None]
            )
        x_e = jnp.einsum("bsec,bsd->becd", disp, x.astype(jnp.float32)).astype(dt)

    x_e = sharder.constrain(x_e, "act_batch", "act_experts", None, None)

    # ---- expert FFNs (SwiGLU) -------------------------------------------- #
    g = jnp.einsum("becd,edf->becf", x_e, params["wg"].astype(dt))
    u = jnp.einsum("becd,edf->becf", x_e, params["wu"].astype(dt))
    h = jax.nn.silu(g) * u
    h = sharder.constrain(h, "act_batch", "act_experts", None, "act_mlp")
    out_e = jnp.einsum("becf,efd->becd", h, params["wd"].astype(dt))
    out_e = sharder.constrain(out_e, "act_batch", "act_experts", None, None)

    # ---- combine ----------------------------------------------------------- #
    if impl == "scatter":
        def row_combine(oer, er, pr, gr):
            # oer [E,C,d]; er,pr,gr [S,K]
            flat = oer.reshape(E * C, d)
            idx = er * C + pr
            idx = jnp.where(idx < E * C, idx, E * C - 1)
            vals = flat[idx.reshape(-1)].reshape(S, K, d)
            return jnp.einsum("skd,sk->sd", vals, gr.astype(dt))

        gates_masked = jnp.where(keep_k, gate_vals, 0.0)
        y = jax.vmap(row_combine)(out_e, eidx_f, pos_f, gates_masked)
    else:
        # combine weights: dispatch one-hots weighted by gates
        cw = jnp.zeros((B, S, E, C), jnp.float32)
        for j in range(K):
            oh_e = jax.nn.one_hot(eidx[..., j], E, dtype=jnp.float32)
            oh_c = jax.nn.one_hot(pos_k[..., j], C, dtype=jnp.float32)
            cw = cw + (
                oh_e[..., None] * oh_c[..., None, :]
                * (gate_vals[..., j] * keep_k[..., j])[..., None, None]
            )
        y = jnp.einsum("bsec,becd->bsd", cw, out_e.astype(jnp.float32)).astype(dt)

    # ---- shared experts (deepseek) ------------------------------------------ #
    if cfg.n_shared_experts:
        y = y + mlp(params["shared"], x, "silu", sharder)

    return y, {"moe_aux": aux_loss, "moe_z": z_loss}
