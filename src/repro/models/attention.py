"""Attention: GQA with full / sliding-window / local / bidirectional masks.

Backends:
  * ``reference`` — materializes the score matrix (small smoke tests, and
    the oracle for kernels/ref.py cross-checks).
  * ``chunked``  — streaming-softmax flash attention in pure JAX
    (lax.scan over KV chunks, fp32 accumulators). Memory-safe at 32k and
    the backend used by the multi-pod dry-run; structurally identical to
    the Pallas kernel.
  * ``pallas``   — the TPU kernel (kernels/flash_attention.py); validated
    on CPU via interpret=True.

Decode uses a positions-array cache that uniformly covers linear caches
(full attention) and ring buffers (sliding-window / local attention —
O(window) memory, which is what makes ``long_500k`` feasible for danube
and recurrentgemma).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.base import ParamSpec
from repro.models.layers import apply_rope

_NEG = -1.0e30


# --------------------------------------------------------------------------- #
# parameter specs
# --------------------------------------------------------------------------- #
def attn_specs(cfg) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    specs = {
        "wq": ParamSpec((d, H, hd), ("embed", "heads", "head_dim"), init="fan_in"),
        "wk": ParamSpec((d, KV, hd), ("embed", "kv_heads", "head_dim"), init="fan_in"),
        "wv": ParamSpec((d, KV, hd), ("embed", "kv_heads", "head_dim"), init="fan_in"),
        "wo": ParamSpec((H, hd, d), ("heads", "head_dim", "embed"), init="fan_in"),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((H, hd), ("heads", "head_dim"), init="zeros")
        specs["bk"] = ParamSpec((KV, hd), ("kv_heads", "head_dim"), init="zeros")
        specs["bv"] = ParamSpec((KV, hd), ("kv_heads", "head_dim"), init="zeros")
    return specs


# --------------------------------------------------------------------------- #
# masks
# --------------------------------------------------------------------------- #
def _mask(q_pos: jax.Array, kv_pos: jax.Array, mode: str,
          window: Optional[int]) -> jax.Array:
    """[S_q, S_k] boolean validity mask."""
    qp = q_pos[:, None]
    kp = kv_pos[None, :]
    if mode == "bidir":
        m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    else:
        m = qp >= kp
    if window is not None:
        m = m & (qp - kp < window)
    return m


# --------------------------------------------------------------------------- #
# full-sequence attention (train / prefill)
# --------------------------------------------------------------------------- #
def _reference_attention(q, k, v, mode, window):
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qr = (q * (D ** -0.5)).reshape(B, S, KV, G, D)
    s = jnp.einsum("bskgd,btkd->bkgst", qr.astype(jnp.float32),
                   k.astype(jnp.float32))
    m = _mask(jnp.arange(S), jnp.arange(T), mode, window)
    s = jnp.where(m[None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)


def _chunked_attention(q, k, v, mode, window, chunk):
    """Streaming-softmax (flash) attention via lax.scan over KV chunks."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    chunk = min(chunk, T)
    if T % chunk != 0:  # pad KV to a chunk multiple; padded keys are masked
        pad = chunk - T % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = k.shape[1]
    nc = Tp // chunk
    qr = (q.astype(jnp.float32) * (D ** -0.5)).reshape(B, S, KV, G, D)
    kc = jnp.moveaxis(k.reshape(B, nc, chunk, KV, D), 1, 0)  # [nc,B,c,KV,D]
    vc = jnp.moveaxis(v.reshape(B, nc, chunk, KV, D), 1, 0)
    q_pos = jnp.arange(S)

    def body(carry, xs):
        m, l, acc = carry
        ki, vi, ci = xs
        s = jnp.einsum("bskgd,bckd->bkgsc", qr, ki.astype(jnp.float32))
        kv_pos = ci * chunk + jnp.arange(chunk)
        valid = _mask(q_pos, kv_pos, mode, window) & (kv_pos < T)[None, :]
        s = jnp.where(valid[None, None, None], s, _NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(valid[None, None, None], p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgsc,bckd->bkgsd", p, vi.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, S), _NEG, jnp.float32)
    l0 = jnp.zeros((B, KV, G, S), jnp.float32)
    a0 = jnp.zeros((B, KV, G, S, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kc, vc, jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, [1, 2], [2, 3]).reshape(B, S, H, D)
    return out.astype(q.dtype)


def multihead_attention(q, k, v, *, mode: str = "causal",
                        window: Optional[int] = None,
                        backend: str = "chunked", chunk: int = 1024):
    """q [B,S,H,D]; k,v [B,T,KV,D] with H % KV == 0 (GQA)."""
    if backend == "reference":
        return _reference_attention(q, k, v, mode, window)
    if backend == "chunked":
        return _chunked_attention(q, k, v, mode, window, chunk)
    if backend == "pallas":
        from repro.kernels import ops as kops

        return kops.flash_attention(q, k, v, causal=(mode != "bidir"),
                                    window=window)
    raise ValueError(f"unknown attention backend {backend}")


# --------------------------------------------------------------------------- #
# block-level forward (projections + rope + attention)
# --------------------------------------------------------------------------- #
def attention_block(params: dict, cfg, sharder, x: jax.Array,
                    positions: jax.Array, *, mode: str,
                    window: Optional[int] = None) -> jax.Array:
    dt = x.dtype
    wq = sharder.gather(params["wq"].astype(dt), "embed", "heads", None)
    wk = sharder.gather(params["wk"].astype(dt), "embed", "kv_heads", None)
    wv = sharder.gather(params["wv"].astype(dt), "embed", "kv_heads", None)
    wo = sharder.gather(params["wo"].astype(dt), "heads", None, "embed")
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    k = jnp.einsum("bsd,dhk->bshk", x, wk)
    v = jnp.einsum("bsd,dhk->bshk", x, wv)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = sharder.constrain(q, "act_batch", None, "act_heads", None)
    k = sharder.constrain(k, "act_batch", None, "kv_heads", None)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    o = multihead_attention(
        q, k, v, mode=mode, window=window,
        backend=cfg.attn_backend, chunk=cfg.attn_chunk,
    )
    o = sharder.constrain(o, "act_batch", None, "act_heads", None)
    return jnp.einsum("bshk,hkd->bsd", o, wo)


# --------------------------------------------------------------------------- #
# decode (single new token against a cache)
# --------------------------------------------------------------------------- #
def cache_specs(cfg, batch: int, max_len: int, *, window: Optional[int]) -> dict:
    """Per-layer KV cache specs. ``window`` bounds the buffer (ring) for
    SWA/local attention; full attention stores max_len."""
    W = min(window, max_len) if window else max_len
    KV, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": ParamSpec((batch, W, KV, hd), ("kv_batch", "kv_seq", "kv_heads", None),
                       init="zeros", dtype=cfg.compute_dtype),
        "v": ParamSpec((batch, W, KV, hd), ("kv_batch", "kv_seq", "kv_heads", None),
                       init="zeros", dtype=cfg.compute_dtype),
        # absolute position stored in each slot; -1 = empty
        "pos": ParamSpec((batch, W), ("kv_batch", "kv_seq"),
                         init="const", scale=-1, dtype="int32"),
    }


def attention_decode(params: dict, cfg, sharder, x: jax.Array,
                     cache: dict, positions: jax.Array, *,
                     window: Optional[int] = None) -> tuple[jax.Array, dict]:
    """x [B,1,d]; positions [B] absolute position of the new token (or
    [3,B] M-RoPE position streams for the VLM — the temporal stream [0]
    drives the cache slot and validity).

    The cache slot is ``pos % W`` (ring buffer); for full attention W is
    max_len so the ring is equivalent to a linear cache.
    """
    dt = x.dtype
    B = x.shape[0]
    W = cache["k"].shape[1]
    if positions.ndim == 2:  # [3, B] M-RoPE streams
        pos_t = positions[0]
        rope_pos = positions[:, :, None]  # [3,B,1]
    else:
        pos_t = positions
        rope_pos = positions[:, None]     # [B,1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = apply_rope(q, rope_pos, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, rope_pos, cfg.rope_theta, cfg.mrope_sections)

    positions = pos_t
    slots = (positions % W).astype(jnp.int32)  # [B]
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, slots].set(k[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, slots].set(v[:, 0].astype(cache["v"].dtype))
    pos_cache = cache["pos"].at[bidx, slots].set(positions.astype(jnp.int32))

    D = q.shape[-1]
    KV = k_cache.shape[2]
    G = q.shape[2] // KV
    qr = (q.astype(jnp.float32) * (D ** -0.5)).reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bwkd->bkgw", qr, k_cache.astype(jnp.float32))
    valid = (pos_cache >= 0) & (pos_cache <= positions[:, None])
    if window is not None:
        valid = valid & (positions[:, None] - pos_cache < window)
    s = jnp.where(valid[:, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkd->bkgd", p, v_cache.astype(jnp.float32))
    o = o.reshape(B, 1, q.shape[2], D).astype(dt)
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt))
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos_cache}
    return y, new_cache
