"""Model assembly for all 10 assigned architectures.

One ``LM`` class covers every family; per-family *blocks* are composed and
run under ``lax.scan`` over stacked layer parameters (constant-size HLO at
any depth) with a configurable remat policy.

Families:
  dense  — [ln → GQA attn → +res] [ln → (SwiGLU|GeLU) MLP → +res]
  moe    — dense block with the FFN replaced by the MoE layer
           (+ optional leading dense layers: deepseek first_k_dense)
  ssm    — [ln → mamba2 mixer → +res]
  hybrid — Griffin pattern (rec, rec, local-attn) scanned as superblocks
           + unrolled remainder blocks; every temporal block is followed
           by its MLP block
  vlm    — dense with M-RoPE positions [3,B,S]; patch-embedding frontend
           stub (assignment: modality frontend provides embeddings)
  audio  — encoder-only dense: bidirectional attention, GeLU FFN, frame
           embedding frontend stub
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import rglru as rg
from repro.models.base import ParamSpec, is_spec


# --------------------------------------------------------------------------- #
# remat policies
# --------------------------------------------------------------------------- #
def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    raise ValueError(f"unknown remat mode {mode}")


def _stack_specs(specs: Any, n: int) -> Any:
    return jax.tree_util.tree_map(lambda s: s.stacked(n), specs, is_leaf=is_spec)


def _maybe_scan(cfg, f, init, xs):
    """lax.scan over stacked layers, or a Python unroll when
    cfg.scan_layers is False (used by the dry-run's per-layer cost probes —
    XLA's cost analysis counts a while-loop body once regardless of trip
    count, so probes must be unrolled)."""
    if cfg.scan_layers:
        return jax.lax.scan(f, init, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        xi = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = f(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


# --------------------------------------------------------------------------- #
# block definitions
# --------------------------------------------------------------------------- #
def dense_block_specs(cfg, *, attn_window: Optional[int], d_ff: Optional[int] = None):
    return {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "attn": attn.attn_specs(cfg),
        "ln2": L.rmsnorm_spec(cfg.d_model),
        "mlp": L.mlp_specs(cfg.d_model, d_ff or cfg.d_ff, cfg.mlp_act),
    }


def moe_block_specs(cfg):
    return {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "attn": attn.attn_specs(cfg),
        "ln2": L.rmsnorm_spec(cfg.d_model),
        "moe": moe_mod.moe_specs(cfg),
    }


def ssm_block_specs(cfg):
    return {"ln": L.rmsnorm_spec(cfg.d_model), "mixer": m2.mamba2_specs(cfg)}


def rec_block_specs(cfg):
    return {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "rec": rg.rglru_specs(cfg),
        "ln2": L.rmsnorm_spec(cfg.d_model),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_act),
    }


def _res(sharder, x):
    # residual-stream layout is THE sharding lever of the §Perf iterations:
    # act_seq->model = Megatron-SP; act_embed->model = activation TP layout
    return sharder.constrain(x, "act_batch", "act_seq", "act_embed")


def _attn_fn(p, cfg, sharder, positions, mode, window):
    fn = lambda h: attn.attention_block(p, cfg, sharder, h, positions,
                                        mode=mode, window=window)
    if cfg.remat_attention:
        fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


def dense_block_fwd(p, cfg, sharder, x, positions, *, mode, window):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    h = sharder.sp_boundary(h)  # explicit bf16 seq all-gather (iteration E)
    h = _attn_fn(p["attn"], cfg, sharder, positions, mode, window)(h)
    x = _res(sharder, x + h)
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    h = sharder.sp_boundary(h)
    h = L.mlp(p["mlp"], h, cfg.mlp_act, sharder)
    return _res(sharder, x + h)


def moe_block_fwd(p, cfg, sharder, x, positions, *, mode, window):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    h = sharder.sp_boundary(h)
    h = _attn_fn(p["attn"], cfg, sharder, positions, mode, window)(h)
    x = _res(sharder, x + h)
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    # iteration I: gather the seq dim BEFORE routing — otherwise each
    # model shard dispatches only its seq slice and the dispatch buffers
    # get all-reduced over the model axis (15 GB/layer/device on grok)
    h = sharder.sp_boundary(h)
    h, aux = moe_mod.moe_block(p["moe"], cfg, sharder, h)
    return _res(sharder, x + h), aux


def ssm_block_fwd(p, cfg, sharder, x):
    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    h = m2.mamba2_block(p["mixer"], cfg, sharder, h)
    return _res(sharder, x + h)


def rec_block_fwd(p, cfg, sharder, x):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    h = rg.rglru_block(p["rec"], cfg, sharder, h)
    x = _res(sharder, x + h)
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    h = L.mlp(p["mlp"], h, cfg.mlp_act, sharder)
    return _res(sharder, x + h)


# --------------------------------------------------------------------------- #
# the LM
# --------------------------------------------------------------------------- #
class LM:
    def __init__(self, cfg):
        self.cfg = cfg

    # ---------------- param specs ---------------- #
    def param_specs(self) -> dict:
        cfg = self.cfg
        specs: dict[str, Any] = {}
        if cfg.frontend == "token":
            specs["embed"] = L.embed_specs(cfg.vocab, cfg.d_model)
        else:
            d_in = cfg.frontend_dim or cfg.d_model
            specs["frontend"] = {"proj": L.frontend_proj_spec(d_in, cfg.d_model)}
        specs["final_norm"] = L.rmsnorm_spec(cfg.d_model)
        specs["unembed"] = L.unembed_spec(cfg.d_model, cfg.vocab)

        fam = cfg.family
        if fam in ("dense", "vlm", "audio"):
            specs["layers"] = _stack_specs(
                dense_block_specs(cfg, attn_window=cfg.swa_window), cfg.n_layers
            )
        elif fam == "moe":
            k = cfg.first_k_dense
            if k:
                specs["dense_layers"] = _stack_specs(
                    dense_block_specs(cfg, attn_window=None), k
                )
            specs["layers"] = _stack_specs(moe_block_specs(cfg), cfg.n_layers - k)
        elif fam == "ssm":
            specs["layers"] = _stack_specs(ssm_block_specs(cfg), cfg.n_layers)
        elif fam == "hybrid":
            n_super, n_tail = self._hybrid_split()
            specs["superblocks"] = _stack_specs(
                {
                    "rec1": rec_block_specs(cfg),
                    "rec2": rec_block_specs(cfg),
                    "attn": dense_block_specs(cfg, attn_window=cfg.local_window),
                },
                n_super,
            )
            specs["tail"] = {
                str(i): rec_block_specs(cfg) for i in range(n_tail)
            }
        else:
            raise ValueError(f"unknown family {fam}")
        return specs

    def _hybrid_split(self) -> tuple[int, int]:
        n_super = self.cfg.n_layers // 3
        n_tail = self.cfg.n_layers - 3 * n_super
        return n_super, n_tail

    # ---------------- embedding in / out ---------------- #
    def _embed_in(self, params, batch, sharder):
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        if cfg.frontend == "token":
            x = L.embed(batch["tokens"], params["embed"]["tok"], cdt)
        else:
            x = L.frontend_proj(batch["embeds"].astype(cdt),
                                params["frontend"]["proj"])
        return sharder.constrain(x, "act_batch", "act_seq", None)

    def _logits_out(self, params, x, sharder):
        cfg = self.cfg
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(x, params["unembed"])
        return sharder.constrain(logits, "act_batch", None, "act_vocab")

    # ---------------- full-sequence forward (train / prefill) ---------------- #
    def forward(self, params, batch, sharder) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        x = self._embed_in(params, batch, sharder)
        positions = batch["positions"]
        aux = {"moe_aux": jnp.zeros((), jnp.float32),
               "moe_z": jnp.zeros((), jnp.float32)}
        mode = "bidir" if cfg.encoder_only else "causal"

        fam = cfg.family
        if fam in ("dense", "vlm", "audio"):
            body = _remat(
                lambda p, h: dense_block_fwd(p, cfg, sharder, h, positions,
                                             mode=mode, window=cfg.swa_window),
                cfg.remat,
            )
            x, _ = _maybe_scan(cfg, lambda c, p: (body(p, c), None), x,
                               params["layers"])
        elif fam == "moe":
            if cfg.first_k_dense:
                dense_body = _remat(
                    lambda p, h: dense_block_fwd(p, cfg, sharder, h, positions,
                                                 mode=mode, window=None),
                    cfg.remat,
                )
                x, _ = _maybe_scan(cfg, lambda c, p: (dense_body(p, c), None), x,
                                   params["dense_layers"])

            moe_body = _remat(
                lambda p, h: moe_block_fwd(p, cfg, sharder, h, positions,
                                           mode=mode, window=None),
                cfg.remat,
            )

            def fm(carry, p):
                x_c, aux_a, aux_z = carry
                x_n, a = moe_body(p, x_c)
                return (x_n, aux_a + a["moe_aux"], aux_z + a["moe_z"]), None

            (x, aux_a, aux_z), _ = _maybe_scan(
                cfg, fm, (x, aux["moe_aux"], aux["moe_z"]), params["layers"]
            )
            aux = {"moe_aux": aux_a, "moe_z": aux_z}
        elif fam == "ssm":
            body = _remat(lambda p, h: ssm_block_fwd(p, cfg, sharder, h),
                          cfg.remat)
            x, _ = _maybe_scan(cfg, lambda c, p: (body(p, c), None), x,
                               params["layers"])
        elif fam == "hybrid":
            def super_fwd(p, h):
                h = rec_block_fwd(p["rec1"], cfg, sharder, h)
                h = rec_block_fwd(p["rec2"], cfg, sharder, h)
                return dense_block_fwd(p["attn"], cfg, sharder, h, positions,
                                       mode="causal", window=cfg.local_window)

            body = _remat(super_fwd, cfg.remat)
            x, _ = _maybe_scan(cfg, lambda c, p: (body(p, c), None), x,
                               params["superblocks"])
            tail_body = _remat(lambda p, h: rec_block_fwd(p, cfg, sharder, h),
                               cfg.remat)
            for i in sorted(params["tail"], key=int):
                x = tail_body(params["tail"][i], x)
        else:
            raise ValueError(fam)

        return self._logits_out(params, x, sharder), aux

    # ---------------- decode ---------------- #
    def cache_specs(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        fam = cfg.family
        if not cfg.supports_decode:
            raise ValueError(f"{cfg.name} is encoder-only: no decode cache")
        if fam in ("dense", "vlm"):
            per = attn.cache_specs(cfg, batch, max_len, window=cfg.swa_window)
            return {"layers": _stack_specs(per, cfg.n_layers)}
        if fam == "moe":
            per = attn.cache_specs(cfg, batch, max_len, window=None)
            out = {"layers": _stack_specs(per, cfg.n_layers - cfg.first_k_dense)}
            if cfg.first_k_dense:
                out["dense_layers"] = _stack_specs(per, cfg.first_k_dense)
            return out
        if fam == "ssm":
            return {"layers": _stack_specs(m2.mamba2_cache_specs(cfg, batch),
                                           cfg.n_layers)}
        if fam == "hybrid":
            n_super, n_tail = self._hybrid_split()
            per_attn = attn.cache_specs(cfg, batch, max_len,
                                        window=cfg.local_window)
            per_rec = rg.rglru_cache_specs(cfg, batch)
            return {
                "superblocks": _stack_specs(
                    {"rec1": per_rec, "rec2": per_rec, "attn": per_attn}, n_super
                ),
                "tail": {str(i): rg.rglru_cache_specs(cfg, batch)
                         for i in range(n_tail)},
            }
        raise ValueError(fam)

    def decode_step(self, params, cache, tokens, positions, sharder):
        """One token for every row. tokens [B] (or embeds [B,1,Din]);
        positions [B] (or [3,B] for vlm). Returns (logits [B,V], cache)."""
        cfg = self.cfg
        self._sharder = sharder
        cdt = jnp.dtype(cfg.compute_dtype)
        if cfg.frontend == "token":
            x = L.embed(tokens[:, None], params["embed"]["tok"], cdt)
        else:
            x = L.frontend_proj(tokens.astype(cdt), params["frontend"]["proj"])

        fam = cfg.family
        if fam in ("dense", "vlm"):
            def body(carry, xs):
                p, c = xs
                y, c2 = self._attn_decode_block(p, c, carry, positions)
                return y, c2

            x, new_layers = _maybe_scan(cfg, body, x, (params["layers"], cache["layers"]))
            new_cache = {"layers": new_layers}
        elif fam == "moe":
            new_cache = {}
            if cfg.first_k_dense:
                def body_d(carry, xs):
                    p, c = xs
                    y, c2 = self._attn_decode_block(p, c, carry, positions,
                                                    dense=True)
                    return y, c2

                x, nd = _maybe_scan(
                    cfg, body_d, x, (params["dense_layers"], cache["dense_layers"])
                )
                new_cache["dense_layers"] = nd

            def body_m(carry, xs):
                p, c = xs
                y, c2 = self._moe_decode_block(p, c, carry, positions)
                return y, c2

            x, nl = _maybe_scan(cfg, body_m, x, (params["layers"], cache["layers"]))
            new_cache["layers"] = nl
        elif fam == "ssm":
            def body_s(carry, xs):
                p, c = xs
                h = L.rmsnorm(carry, p["ln"], cfg.norm_eps)
                h, c2 = m2.mamba2_decode(p["mixer"], cfg, sharder, h, c)
                return carry + h, c2

            x, nl = _maybe_scan(cfg, body_s, x, (params["layers"], cache["layers"]))
            new_cache = {"layers": nl}
        elif fam == "hybrid":
            def body_h(carry, xs):
                p, c = xs
                y = carry
                y, c1 = self._rec_decode_block(p["rec1"], c["rec1"], y)
                y, c2 = self._rec_decode_block(p["rec2"], c["rec2"], y)
                y, c3 = self._attn_decode_block(
                    p["attn"], c["attn"], y, positions, window=cfg.local_window
                )
                return y, {"rec1": c1, "rec2": c2, "attn": c3}

            x, nsb = _maybe_scan(
                cfg, body_h, x, (params["superblocks"], cache["superblocks"])
            )
            new_tail = {}
            for i in sorted(params["tail"], key=int):
                x, ct = self._rec_decode_block(
                    params["tail"][i], cache["tail"][i], x
                )
                new_tail[i] = ct
            new_cache = {"superblocks": nsb, "tail": new_tail}
        else:
            raise ValueError(fam)

        logits = self._logits_out(params, x, sharder)[:, 0]
        return logits, new_cache

    # decode block helpers ------------------------------------------------- #
    def _attn_decode_block(self, p, c, x, positions, *, window=None, dense=None):
        cfg = self.cfg
        win = window if window is not None else cfg.swa_window
        pos_b = positions if positions.ndim == 1 else positions[0]
        sharder = self._sharder
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        h, c2 = attn.attention_decode(
            p["attn"], cfg, sharder, h,
            c, positions if cfg.mrope_sections else pos_b, window=win,
        )
        x = x + h
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if "mlp" in p:
            h = L.mlp(p["mlp"], h, cfg.mlp_act, sharder)
        else:
            # decode-time MoE: route the whole batch as ONE group ([B,1,d]
            # -> [1,B,d]) so expert capacity is shared across rows instead
            # of a per-row floor — removes the ~30x dead-slot compute of
            # per-row capacity at S=1 (§Perf iteration H).
            hh = jnp.swapaxes(h, 0, 1)
            hh, _ = moe_mod.moe_block(p["moe"], cfg, sharder, hh)
            h = jnp.swapaxes(hh, 0, 1)
        return x + h, c2

    def _moe_decode_block(self, p, c, x, positions):
        return self._attn_decode_block(p, c, x, positions)

    def _rec_decode_block(self, p, c, x):
        cfg = self.cfg
        sharder = self._sharder
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        h, c2 = rg.rglru_decode(p["rec"], cfg, sharder, h, c)
        x = x + h
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        h = L.mlp(p["mlp"], h, cfg.mlp_act, sharder)
        return x + h, c2

    # decode needs the sharder on self (scan bodies take fixed signatures)
    _sharder = None

    def bind_sharder(self, sharder) -> "LM":
        self._sharder = sharder
        return self
