"""Shared layers: RMSNorm, embeddings, RoPE (incl. M-RoPE), MLPs."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.base import ParamSpec


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #
def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="ones")


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------- #
# embeddings
# --------------------------------------------------------------------------- #
def embed_specs(vocab: int, d: int) -> dict:
    return {
        "tok": ParamSpec((vocab, d), ("vocab", "embed"), init="embed", scale=0.02),
    }


def unembed_spec(d: int, vocab: int) -> ParamSpec:
    return ParamSpec((d, vocab), ("embed", "vocab"), init="fan_in")


def embed(tokens: jax.Array, tok_w: jax.Array, compute_dtype) -> jax.Array:
    # gather on a (vocab->model)-sharded table: GSPMD lowers to a masked
    # local gather + all-reduce
    return tok_w.astype(compute_dtype)[tokens]


def unembed(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))


# --------------------------------------------------------------------------- #
# RoPE (+ M-RoPE for qwen2-vl)
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(
    x: jax.Array,              # [B, S, H, D]
    positions: jax.Array,      # [B, S] int32  or  [3, B, S] for M-RoPE
    theta: float,
    mrope_sections: Optional[tuple[int, ...]] = None,
) -> jax.Array:
    """Rotary embedding. With ``mrope_sections`` (in *pair* units summing to
    D/2), frequency bands are driven by the (temporal, h, w) position streams
    of qwen2-vl's multimodal RoPE."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [D/2]
    if mrope_sections is None:
        if positions.ndim == 3:
            positions = positions[0]
        angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,D/2]
    else:
        assert positions.ndim == 3, "M-RoPE needs [3, B, S] positions"
        assert sum(mrope_sections) == d // 2, (mrope_sections, d)
        sect_pos = []
        for i, n in enumerate(mrope_sections):
            sect_pos.append(
                jnp.broadcast_to(
                    positions[i][..., None].astype(jnp.float32),
                    positions.shape[1:] + (n,),
                )
            )
        pos_per_freq = jnp.concatenate(sect_pos, axis=-1)  # [B,S,D/2]
        angles = pos_per_freq * freqs
    cos = jnp.cos(angles)[:, :, None, :]  # [B,S,1,D/2]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# MLPs
# --------------------------------------------------------------------------- #
def mlp_specs(d: int, ff: int, act: str) -> dict:
    if act == "silu":  # SwiGLU: gate+up+down
        return {
            "gate": ParamSpec((d, ff), ("embed", "mlp"), init="fan_in"),
            "up": ParamSpec((d, ff), ("embed", "mlp"), init="fan_in"),
            "down": ParamSpec((ff, d), ("mlp", "embed"), init="fan_in"),
        }
    # classic 2-matrix GeLU FFN (hubert)
    return {
        "w1": ParamSpec((d, ff), ("embed", "mlp"), init="fan_in"),
        "b1": ParamSpec((ff,), ("mlp",), init="zeros"),
        "w2": ParamSpec((ff, d), ("mlp", "embed"), init="fan_in"),
        "b2": ParamSpec((d,), ("embed",), init="zeros"),
    }


def mlp(params: dict, x: jax.Array, act: str, sharder=None) -> jax.Array:
    dt = x.dtype

    def g_(w, *axes):  # FSDP use-time gather (no-op unless enabled)
        w = w.astype(dt)
        return sharder.gather(w, *axes) if sharder is not None else w

    if act == "silu":
        g = jnp.einsum("bsd,df->bsf", x, g_(params["gate"], "embed", "mlp"))
        u = jnp.einsum("bsd,df->bsf", x, g_(params["up"], "embed", "mlp"))
        h = jax.nn.silu(g) * u
        if sharder is not None:
            h = sharder.constrain(h, "act_batch", None, "act_mlp")
        return jnp.einsum("bsf,fd->bsd", h, g_(params["down"], "mlp", "embed"))
    h = jnp.einsum("bsd,df->bsf", x, g_(params["w1"], "embed", "mlp"))
    h = jax.nn.gelu(h + params["b1"].astype(dt))
    if sharder is not None:
        h = sharder.constrain(h, "act_batch", None, "act_mlp")
    return jnp.einsum("bsf,fd->bsd", h, g_(params["w2"], "mlp", "embed")) \
        + params["b2"].astype(dt)


# --------------------------------------------------------------------------- #
# modality frontends (stubs per assignment: precomputed patch/frame embeds)
# --------------------------------------------------------------------------- #
def frontend_proj_spec(d_in: int, d: int) -> ParamSpec:
    return ParamSpec((d_in, d), ("embed", None), init="fan_in")


def frontend_proj(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.einsum("bsi,id->bsd", x, w.astype(x.dtype))
